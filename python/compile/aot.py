"""AOT build: train (cached), emit datasets, lower entry points to HLO text.

HLO *text* is the interchange format (NOT ``.serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run from ``python/``:  ``python -m compile.aot --out ../artifacts``
The Makefile invokes this once; nothing here runs on the request path.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import taskspec as T
from . import train as TR

# training budget per profile (tiny is never trained — CI shapes only)
TRAIN_STEPS = {"s4": 2000, "m6": 800}
TRAIN_BATCH = {"s4": 32, "m6": 24}
TRAIN_LR = {"s4": 2e-3, "m6": 1.5e-3}
EVAL_SAMPLES = {"tiny": 16, "s4": 200, "m6": 200, "x16": 24}


def to_hlo_text(fn, arg_specs) -> str:
    # keep_unused=True: the rust runtime feeds every weight array
    # positionally, so the lowered module must keep all parameters even
    # when an entry point doesn't touch some of them.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec_dict(s):
    dt = {np.dtype("int32"): "i32", np.dtype("float32"): "f32"}[
        np.dtype(s.dtype)]
    return {"shape": list(s.shape), "dtype": dt}


def _param_specs(cfg):
    return [jax.ShapeDtypeStruct(shape, np.float32)
            for _, shape in M.param_specs(cfg)]


def build_profile(cfg: T.Profile, out_dir: str, force_train: bool,
                  steps_override: int | None):
    entry_meta = {}
    pspecs = _param_specs(cfg)

    # ---- weights ---------------------------------------------------------
    wfile = f"{cfg.name}_weights.bin"
    wpath = os.path.join(out_dir, wfile)
    report = {}
    if cfg.name in TRAIN_STEPS:
        if force_train or not os.path.exists(wpath):
            steps = steps_override or TRAIN_STEPS[cfg.name]
            print(f"[aot] training {cfg.name} for {steps} steps", flush=True)
            params = TR.train(cfg, steps=steps, batch=TRAIN_BATCH[cfg.name],
                              lr=TRAIN_LR[cfg.name])
            TR.save_weights(wpath, cfg, params)
        else:
            print(f"[aot] reusing cached weights {wpath}", flush=True)
            params = TR.load_weights(wpath, cfg)
        import jax.numpy as jnp
        em, per = TR.evaluate(cfg, [jnp.asarray(p) for p in params],
                              D.SampleGen(cfg, "hotpot-sim", seed=999), 24)
        report["exact_match_oracle"] = em
        report["per_type"] = {k: a for k, (a, _) in per.items()}
        print(f"[aot] {cfg.name} oracle EM={em:.3f} {report['per_type']}",
              flush=True)
    else:
        if force_train or not os.path.exists(wpath):
            TR.save_weights(wpath, cfg, M.init_params(cfg, seed=7))

    # ---- lower entry points ---------------------------------------------
    for name, (fn, arg_specs, needs_w) in M.entrypoints(cfg).items():
        t0 = time.time()
        fname = f"{cfg.name}_{name}.hlo.txt"
        fpath = os.path.join(out_dir, fname)
        if needs_w:
            out_specs = jax.eval_shape(fn, pspecs, *arg_specs)
        else:
            out_specs = jax.eval_shape(fn, *arg_specs)
        if not os.path.exists(fpath) or force_train:
            if needs_w:
                text = to_hlo_text(lambda p, *a: fn(p, *a),
                                   [pspecs] + arg_specs)
            else:
                text = to_hlo_text(fn, arg_specs)
            with open(fpath, "w") as f:
                f.write(text)
            print(f"[aot] lowered {fname} ({len(text) / 1e6:.2f} MB, "
                  f"{time.time() - t0:.1f}s)", flush=True)
        entry_meta[name] = {
            "file": fname,
            "needs_weights": needs_w,
            "args": [_spec_dict(s) for s in arg_specs],
            "outputs": [_spec_dict(s) for s in jax.tree.leaves(out_specs)],
        }

    return {
        "config": cfg.as_dict(),
        "weights": wfile,
        "n_weight_arrays": M.n_params_arrays(cfg),
        "entrypoints": entry_meta,
        "train_report": report,
    }


def build_datasets(cfg: T.Profile, out_dir: str, n: int):
    """Eval datasets are keyed by the document geometry so model variants
    with identical task shapes (s4 / m6) share files."""
    shape_key = f"d{cfg.n_docs}x{cfg.doc_len}"
    ds_dir = os.path.join(out_dir, "datasets")
    os.makedirs(ds_dir, exist_ok=True)
    out = {}
    for ds in T.DATASETS:
        fname = f"{shape_key}_{ds}.json"
        fpath = os.path.join(ds_dir, fname)
        if not os.path.exists(fpath):
            cnt = D.write_eval_dataset(fpath, cfg, ds, n, seed=4242)
            print(f"[aot] dataset {fname}: {cnt} samples", flush=True)
        out[ds] = os.path.join("datasets", fname)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profiles", default="tiny,s4,m6,x16")
    ap.add_argument("--force-train", action="store_true")
    ap.add_argument("--steps", type=int, default=None,
                    help="override training steps (all trained profiles)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"version": 1, "profiles": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for pname in args.profiles.split(","):
        pname = pname.strip()
        cfg = T.PROFILES[pname]
        meta = build_profile(cfg, args.out, args.force_train, args.steps)
        meta["datasets"] = build_datasets(cfg, args.out,
                                          EVAL_SAMPLES[pname])
        manifest["profiles"][pname] = meta
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"[aot] profile {pname} done", flush=True)

    print(f"[aot] manifest -> {manifest_path}", flush=True)


if __name__ == "__main__":
    main()
