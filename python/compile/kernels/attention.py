"""Masked flash attention over a padded sparse KV buffer (Pallas, L1).

This is the serving hot-spot: every decode step attends from one query
token to the assembled multi-context sparse KV cache. The kernel streams
K/V through VMEM in ``tile``-sized chunks with an online-softmax
(running max / running denominator) so the working set per grid step is

    q:      [Dh]                     (resident)
    k, v:   2 x [tile, Dh]           (streamed HBM -> VMEM)
    valid:  [tile]                   (streamed)
    carry:  m, l scalars + acc[Dh]   (registers)

which is the TPU re-think of the paper's GPU gather+attend: the sparse
buffer is already block-assembled by the rust coordinator, so the
HBM->VMEM schedule is a dense sequential stream (no gather on the hot
path). On real TPU hardware the natural tile is (128, Dh); on the CPU
interpret path the tile only shapes the loop structure.

Invalid (padding) slots carry ``valid == 0`` and are masked to -1e30
*before* the online max, so they contribute exp(-inf) = 0 regardless of
buffer contents.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_TILE = 16


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, *, tile: int):
    _, seq, head_dim = k_ref.shape
    q = q_ref[0]
    scale = 1.0 / np.sqrt(head_dim)

    def body(i, carry):
        m, l, acc = carry
        ks = k_ref[0, pl.dslice(i * tile, tile), :]
        vs = v_ref[0, pl.dslice(i * tile, tile), :]
        va = valid_ref[pl.dslice(i * tile, tile)]
        s = (ks @ q) * scale + (va - 1.0) * 1e30
        m2 = jnp.maximum(m, jnp.max(s))
        p = jnp.exp(s - m2)
        corr = jnp.exp(m - m2)
        return m2, l * corr + jnp.sum(p), acc * corr + p @ vs

    init = (jnp.float32(-1e30), jnp.float32(0.0),
            jnp.zeros((head_dim,), jnp.float32))
    _, l, acc = jax.lax.fori_loop(0, seq // tile, body, init)
    o_ref[0, :] = acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("tile",))
def masked_flash_attention(q, k, v, valid, tile: int = DEFAULT_TILE):
    """Single-token attention: q [H, Dh], k/v [H, S, Dh], valid [S] -> [H, Dh].

    S is padded to a multiple of ``tile`` internally; padded slots are
    masked out.
    """
    heads, head_dim = q.shape
    seq = k.shape[1]
    pad = (-seq) % tile
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    seq_p = seq + pad
    kernel = functools.partial(_decode_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(heads,),
        in_specs=[
            pl.BlockSpec((1, head_dim), lambda h: (h, 0)),
            pl.BlockSpec((1, seq_p, head_dim), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, seq_p, head_dim), lambda h: (h, 0, 0)),
            pl.BlockSpec((seq_p,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, head_dim), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, head_dim), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(q, k, v, valid)
