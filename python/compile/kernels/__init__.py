"""Layer-1 Pallas kernels (interpret=True; see DESIGN.md §4).

``attention.masked_flash_attention`` — decode hot-path attention over the
assembled sparse KV buffer.
``block_score.block_score`` — block-mean-K scoring for the KV selection
module.
``ref`` — pure-jnp oracles used by the hypothesis test sweeps.
"""
from .attention import masked_flash_attention  # noqa: F401
from .block_score import block_score  # noqa: F401
