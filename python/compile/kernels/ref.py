"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package must match its oracle to float32 tolerance
across the shape/dtype sweeps in ``python/tests/test_kernels.py``.
"""
import jax.numpy as jnp
import numpy as np


def masked_attention_ref(q, k, v, valid):
    """q [H, Dh], k/v [H, S, Dh], valid [S] -> [H, Dh]."""
    head_dim = q.shape[-1]
    s = jnp.einsum("hd,hsd->hs", q, k) / np.sqrt(head_dim)
    s = jnp.where(valid[None, :] > 0, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hs,hsd->hd", p, v)


def block_score_ref(q, k, valid, block_size: int):
    """q [H, Dh], k [H, S, Dh], valid [S] -> [S // block_size]."""
    heads, seq, head_dim = k.shape
    n_blocks = seq // block_size
    kb = k.reshape(heads, n_blocks, block_size, head_dim)
    vb = valid.reshape(n_blocks, block_size)
    denom = jnp.maximum(vb.sum(axis=-1), 1.0)  # [NB]
    kbar = (kb * vb[None, :, :, None]).sum(axis=2) / denom[None, :, None]
    return jnp.einsum("hd,hbd->b", q, kbar) / heads
