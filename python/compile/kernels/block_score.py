"""Block scoring kernel (Pallas, L1): <Q-hat, mean-K per block>.

The paper's KV Selection Module manages the cache at block granularity
and represents "each block ... by the mean vector of its constituent
token caches" (§3.2). This kernel fuses the per-block mean-K reduction
with the personalized-query dot product so the coordinator can offload
scoring ("the sparsification process is accelerated by vector databases
and GPUs", §4.3).

One grid step scores one block: load K tile [H, B, Dh] + valid [B],
reduce to the valid-token mean [H, Dh], dot with q-hat [H, Dh], average
over heads — an MXU-shaped [B, Dh] x [Dh] contraction per head on real
hardware.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(q_ref, k_ref, valid_ref, o_ref):
    heads, block, _ = k_ref.shape
    va = valid_ref[...]
    denom = jnp.maximum(jnp.sum(va), 1.0)
    q = q_ref[...]
    k = k_ref[...]
    kbar = jnp.sum(k * va[None, :, None], axis=1) / denom  # [H, Dh]
    o_ref[0] = jnp.sum(q * kbar) / heads


@functools.partial(jax.jit, static_argnames=("block_size",))
def block_score(q, k, valid, block_size: int):
    """q [H, Dh], k [H, S, Dh], valid [S] -> scores [S // block_size].

    Blocks with no valid token score the mean over zeros = 0 direction;
    callers mask those out via the block-validity they already track.
    """
    heads, seq, head_dim = k.shape
    assert seq % block_size == 0, (seq, block_size)
    n_blocks = seq // block_size
    return pl.pallas_call(
        _score_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((heads, head_dim), lambda b: (0, 0)),
            pl.BlockSpec((heads, block_size, head_dim), lambda b: (0, b, 0)),
            pl.BlockSpec((block_size,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        interpret=True,
    )(q, k, valid)
