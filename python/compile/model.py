"""Layer-2 JAX model: decoder-only transformer + SamKV serving entry points.

The model (RMSNorm / RoPE / MHA / GELU MLP, logits tied to the embedding)
is expressed over a *flat list* of parameter arrays so the rust runtime
can feed weights positionally without a pytree codec:

    params[0]                 embed      [V, D]
    params[1 + 8*l + 0]       ln1_g      [D]
    params[1 + 8*l + 1]       wq         [D, H*Dh]
    params[1 + 8*l + 2]       wk         [D, H*Dh]
    params[1 + 8*l + 3]       wv         [D, H*Dh]
    params[1 + 8*l + 4]       wo         [H*Dh, D]
    params[1 + 8*l + 5]       ln2_g      [D]
    params[1 + 8*l + 6]       w1         [D, F]
    params[1 + 8*l + 7]       w2         [F, D]
    params[1 + 8*L]           lnf_g      [D]

AOT entry points (static shapes fixed by a ``taskspec.Profile``):
``prefill_doc``, ``prefill_full``, ``query_embed``, ``recompute`` (sparse
buffer), ``recompute_full`` (CacheBlend/EPIC path), ``decode_step``
(Pallas hot path, lowered per buffer as ``decode_sparse``/``decode_full``
plus the lane-padded ``decode_{sparse,full}_batched`` multi-sequence
variants — one XLA execution per fused serving round), plus
``score_blocks`` wrapping the L1 block-score kernel. KV caches travel as
``[L, 2, H, S, Dh]`` tensors (axis 1 = K/V).

All attention masking is *position-based*: a query at global position p
attends keys with position <= p and valid == 1. Keys are stored
post-RoPE, so KV computed at colliding local positions (independent
per-document prefill) reproduces exactly the cross-context deficiency
the paper addresses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import masked_flash_attention
from .kernels.block_score import block_score
from . import taskspec as T

NEG = -1e30


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def n_params_arrays(cfg: T.Profile) -> int:
    return 2 + 8 * cfg.n_layers


def param_specs(cfg: T.Profile):
    """Ordered (name, shape) list — mirrored by rust/src/model/weights.rs."""
    d, hd, f = cfg.d_model, cfg.n_heads * cfg.head_dim, cfg.d_ff
    specs = [("embed", (cfg.vocab, d))]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.ln1_g", (d,)),
            (f"l{l}.wq", (d, hd)),
            (f"l{l}.wk", (d, hd)),
            (f"l{l}.wv", (d, hd)),
            (f"l{l}.wo", (hd, d)),
            (f"l{l}.ln2_g", (d,)),
            (f"l{l}.w1", (d, f)),
            (f"l{l}.w2", (f, d)),
        ]
    specs.append(("lnf_g", (d,)))
    return specs


def init_params(cfg: T.Profile, seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in param_specs(cfg):
        if name.endswith("_g"):
            out.append(np.ones(shape, np.float32))
        elif name == "embed":
            out.append((rng.standard_normal(shape) * 0.02).astype(np.float32))
        else:
            fan_in = shape[0]
            out.append((rng.standard_normal(shape) / np.sqrt(fan_in))
                       .astype(np.float32))
    return out


# --------------------------------------------------------------------------
# primitive blocks
# --------------------------------------------------------------------------

def rmsnorm(x, g):
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def rope(x, positions, theta):
    """x [..., S, H, Dh] rotated by positions [S]."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(ang)[:, None, :]  # [S, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _qkv(cfg, params, l, xn, positions):
    """Project + rotate. xn [S, D] -> q, k, v each [H, S, Dh]."""
    base = 1 + 8 * l
    s = xn.shape[0]
    shp = (s, cfg.n_heads, cfg.head_dim)
    q = rope((xn @ params[base + 1]).reshape(shp), positions, cfg.rope_theta)
    k = rope((xn @ params[base + 2]).reshape(shp), positions, cfg.rope_theta)
    v = (xn @ params[base + 3]).reshape(shp)
    return (q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2))


def _mlp(cfg, params, l, h):
    base = 1 + 8 * l
    xn = rmsnorm(h, params[base + 5])
    return h + jax.nn.gelu(xn @ params[base + 6]) @ params[base + 7]


def _attn_full(cfg, q, k, v, mask):
    """q,k,v [H, S, Dh]; mask [Sq, Sk] (1 = attend) -> out [Sq, H*Dh], probs."""
    scale = 1.0 / np.sqrt(cfg.head_dim)
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale + (mask[None] - 1.0) * 1e30
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", p, v)
    return o.transpose(1, 0, 2).reshape(q.shape[1], -1), p


def _wo(params, l, o):
    return o @ params[1 + 8 * l + 4]


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def prefill_doc(cfg: T.Profile, params, tokens, pos_offset):
    """Independent per-document prefill.

    tokens [Ld] i32, pos_offset scalar i32 ->
      kv      [L, 2, H, Ld, Dh]
      attn    [L, H, Ld, Ld]   (softmax probs; Appendix-A analytics input)
      q_local [L, H, Dh]       (mean post-RoPE Q over the local window; the
                                per-document "local Q cache" of Eq. 1)
    """
    ld = cfg.doc_len
    positions = pos_offset + jnp.arange(ld, dtype=jnp.int32)
    causal = jnp.tril(jnp.ones((ld, ld), jnp.float32))
    h = params[0][tokens]
    kvs, attns, qloc = [], [], []
    local = cfg.local_blocks * cfg.block_size
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[1 + 8 * l])
        q, k, v = _qkv(cfg, params, l, xn, positions)
        o, p = _attn_full(cfg, q, k, v, causal)
        h = h + _wo(params, l, o)
        h = _mlp(cfg, params, l, h)
        kvs.append(jnp.stack([k, v]))
        attns.append(p)
        qloc.append(jnp.mean(q[:, ld - local:, :], axis=1))
    return (jnp.stack(kvs), jnp.stack(attns), jnp.stack(qloc))


def prefill_full(cfg: T.Profile, params, tokens, valid):
    """Joint causal prefill over the whole padded sequence (Recompute).

    tokens [Lt] i32, valid [Lt] f32 -> kv [L, 2, H, Lt, Dh]
    """
    lt = cfg.full_len
    positions = jnp.arange(lt, dtype=jnp.int32)
    mask = jnp.tril(jnp.ones((lt, lt), jnp.float32)) * valid[None, :]
    h = params[0][tokens]
    kvs = []
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[1 + 8 * l])
        q, k, v = _qkv(cfg, params, l, xn, positions)
        o, _ = _attn_full(cfg, q, k, v, mask)
        h = h + _wo(params, l, o)
        h = _mlp(cfg, params, l, h)
        kvs.append(jnp.stack([k, v]))
    return (jnp.stack(kvs),)


def forward_logits(cfg: T.Profile, params, tokens, valid):
    """Training forward: logits [Lt, V] over the padded joint sequence."""
    lt = tokens.shape[0]
    positions = jnp.arange(lt, dtype=jnp.int32)
    mask = jnp.tril(jnp.ones((lt, lt), jnp.float32)) * valid[None, :]
    h = params[0][tokens]
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[1 + 8 * l])
        q, k, v = _qkv(cfg, params, l, xn, positions)
        o, _ = _attn_full(cfg, q, k, v, mask)
        h = h + _wo(params, l, o)
        h = _mlp(cfg, params, l, h)
    return rmsnorm(h, params[-1]) @ params[0].T


def query_embed(cfg: T.Profile, params, q_tokens, comp_kv, comp_valid, q_pos):
    """Incremental prefill of the user query over the compressed cache.

    The compressed cache is the concatenated init+local KV of all docs
    (§3.1 "composite Cache unit"). Returns the generic query vector
    Q_que (per-layer mean-pooled post-RoPE Q) plus the query's own KV.

    q_tokens [Lq] i32, comp_kv [L, 2, H, Lc, Dh], comp_valid [Lc] f32,
    q_pos [Lq] i32 ->
      q_que [L, H, Dh], q_kv [L, 2, H, Lq, Dh]
    """
    lq = T.QUERY_LEN
    h = params[0][q_tokens]
    causal = jnp.tril(jnp.ones((lq, lq), jnp.float32))
    q_ques, q_kvs = [], []
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[1 + 8 * l])
        q, k, v = _qkv(cfg, params, l, xn, q_pos)
        k_cat = jnp.concatenate([comp_kv[l, 0], k], axis=1)
        v_cat = jnp.concatenate([comp_kv[l, 1], v], axis=1)
        mask = jnp.concatenate(
            [jnp.broadcast_to(comp_valid[None, :], (lq, comp_valid.shape[0])),
             causal], axis=1)
        o, _ = _attn_full(cfg, q, k_cat, v_cat, mask)
        h = h + _wo(params, l, o)
        h = _mlp(cfg, params, l, h)
        q_ques.append(jnp.mean(q, axis=1))
        q_kvs.append(jnp.stack([k, v]))
    return (jnp.stack(q_ques), jnp.stack(q_kvs))


def recompute(cfg: T.Profile, params, tokens, positions, kv_in, rec_mask,
              valid, length=None):
    """Fig.-5 layer-wise partial recomputation over a (sparse) buffer.

    tokens [S] i32      token ids occupying the buffer slots
    positions [S] i32   *global* (training-layout) positions, ascending
    kv_in [L,2,H,S,Dh]  reused per-document KV (local-position RoPE)
    rec_mask [L,S] f32  1 = recompute this slot's KV at this layer
    valid [S] f32       1 = slot occupied

    Per the paper's two rules: outputs are computed from layer 1 upward
    for every slot (rule 1 — a superset of "all slots needed later"),
    and at layer n the merged cache ``where(rec_mask, fresh, cached)``
    is used both for attention and as the layer's output KV (rule 2).
    Returns kv_out [L,2,H,S,Dh].
    """
    s = tokens.shape[0]
    allow = (positions[None, :] <= positions[:, None]).astype(jnp.float32)
    mask = allow * valid[None, :]
    h = params[0][tokens]
    kv_out = []
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[1 + 8 * l])
        q, k, v = _qkv(cfg, params, l, xn, positions)
        m = rec_mask[l][None, :, None]
        k_m = k * m + kv_in[l, 0] * (1.0 - m)
        v_m = v * m + kv_in[l, 1] * (1.0 - m)
        o, _ = _attn_full(cfg, q, k_m, v_m, mask)
        h = h + _wo(params, l, o)
        h = _mlp(cfg, params, l, h)
        kv_out.append(jnp.stack([k_m, v_m]))
    return (jnp.stack(kv_out),)


def decode_step(cfg: T.Profile, params, token, pos, slot, kv, kv_valid):
    """One autoregressive step over the assembled cache (Pallas hot path).

    token/pos/slot scalars i32, kv [L,2,H,S,Dh], kv_valid [S] f32 ->
      logits [V], k_new [L,H,Dh], v_new [L,H,Dh]

    The token's own K/V is placed into ``slot`` before attending (the
    rust coordinator mirrors the write into its host buffer afterwards).
    """
    s = kv.shape[3]
    h = params[0][token][None, :]  # [1, D]
    pos_v = pos[None] if pos.ndim == 0 else pos
    valid2 = jnp.maximum(kv_valid,
                         (jnp.arange(s) == slot).astype(jnp.float32))
    k_news, v_news = [], []
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[1 + 8 * l])
        q, k, v = _qkv(cfg, params, l, xn, pos_v)  # [H, 1, Dh]
        k_buf = jax.lax.dynamic_update_slice(kv[l, 0], k, (0, slot, 0))
        v_buf = jax.lax.dynamic_update_slice(kv[l, 1], v, (0, slot, 0))
        o = masked_flash_attention(q[:, 0, :], k_buf, v_buf, valid2)
        h = h + _wo(params, l, o.reshape(1, -1))
        h = _mlp(cfg, params, l, h)
        k_news.append(k[:, 0, :])
        v_news.append(v[:, 0, :])
    logits = (rmsnorm(h, params[-1]) @ params[0].T)[0]
    return (logits, jnp.stack(k_news), jnp.stack(v_news))


def decode_step_batched(cfg: T.Profile, params, tokens, pos, slot, kv,
                        kv_valid, live):
    """Lane-padded multi-sequence decode: one XLA execution per fused round.

    tokens/pos/slot [B] i32, kv [B,L,2,H,S,Dh], kv_valid [B,S] f32,
    live [B] f32 (1 = lane occupied, 0 = padding) ->
      logits [B,V], k_new [B,L,H,Dh], v_new [B,L,H,Dh]

    Lanes are *unrolled* (not vmapped), so each lane lowers to exactly
    the per-lane ops of ``decode_step`` — batched and scalar decode keep
    bitwise-identical per-lane arithmetic, which the rust token-identity
    parity tests rely on. Dead lanes still run on their zero padding
    (harmless: ``decode_step`` forces the written slot valid, so softmax
    never sees an empty row) and their outputs are zeroed via ``live``.
    """
    b = tokens.shape[0]
    logits, k_news, v_news = [], [], []
    for i in range(b):
        lg, kn, vn = decode_step(cfg, params, tokens[i], pos[i], slot[i],
                                 kv[i], kv_valid[i])
        logits.append(lg * live[i])
        k_news.append(kn * live[i])
        v_news.append(vn * live[i])
    return (jnp.stack(logits), jnp.stack(k_news), jnp.stack(v_news))


def score_blocks(cfg: T.Profile, q_hat, k_cache, valid):  # weight-free
    """Offloaded selection scoring (L1 block_score kernel).

    q_hat [L, H, Dh] (personalized query), k_cache [L, H, S, Dh],
    valid [S] -> scores [L, S/block]. The coordinator consumes the
    per-layer scores for Eq. 2/3.
    """
    outs = [block_score(q_hat[l], k_cache[l], valid, cfg.block_size)
            for l in range(cfg.n_layers)]
    return (jnp.stack(outs),)


# --------------------------------------------------------------------------
# entry-point registry for AOT lowering
# --------------------------------------------------------------------------

def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entrypoints(cfg: T.Profile):
    """name -> (fn(params, *args), example_arg_specs, needs_weights).

    ``score_blocks`` is weight-free (it only touches cached K and the
    personalized query), so the coordinator can invoke it without
    shipping the model weights.
    """
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    ld, lt, lq, lc = cfg.doc_len, cfg.full_len, T.QUERY_LEN, cfg.comp_len
    ssp = cfg.sparse_len
    nb = cfg.decode_lanes
    return {
        "prefill_doc": (
            functools.partial(prefill_doc, cfg),
            [_i32(ld), _i32()], True,
        ),
        "prefill_full": (
            functools.partial(prefill_full, cfg),
            [_i32(lt), _f32(lt)], True,
        ),
        "query_embed": (
            functools.partial(query_embed, cfg),
            [_i32(lq), _f32(L, 2, H, lc, Dh), _f32(lc), _i32(lq)], True,
        ),
        "recompute": (
            functools.partial(recompute, cfg),
            [_i32(ssp), _i32(ssp), _f32(L, 2, H, ssp, Dh), _f32(L, ssp),
             _f32(ssp)], True,
        ),
        "recompute_full": (
            functools.partial(recompute, cfg),
            [_i32(lt), _i32(lt), _f32(L, 2, H, lt, Dh), _f32(L, lt),
             _f32(lt)], True,
        ),
        "decode_sparse": (
            functools.partial(decode_step, cfg),
            [_i32(), _i32(), _i32(), _f32(L, 2, H, ssp, Dh), _f32(ssp)],
            True,
        ),
        "decode_full": (
            functools.partial(decode_step, cfg),
            [_i32(), _i32(), _i32(), _f32(L, 2, H, lt, Dh), _f32(lt)],
            True,
        ),
        "decode_sparse_batched": (
            functools.partial(decode_step_batched, cfg),
            [_i32(nb), _i32(nb), _i32(nb), _f32(nb, L, 2, H, ssp, Dh),
             _f32(nb, ssp), _f32(nb)], True,
        ),
        "decode_full_batched": (
            functools.partial(decode_step_batched, cfg),
            [_i32(nb), _i32(nb), _i32(nb), _f32(nb, L, 2, H, lt, Dh),
             _f32(nb, lt), _f32(nb)], True,
        ),
        "score_blocks": (
            functools.partial(score_blocks, cfg),
            [_f32(L, H, Dh), _f32(L, H, ld, Dh), _f32(ld)], False,
        ),
    }
