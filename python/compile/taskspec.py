"""Canonical task / vocabulary / layout specification.

This file is the single source of truth for the synthetic multi-document
QA task that substitutes for LongBench (see DESIGN.md §2). The constants
here are mirrored by ``rust/src/tokenizer.rs`` — change both together.

Vocabulary layout (size 256):

    0            PAD
    1            BOS     (every document starts with BOS)
    2            QUERY   (start of the user query)
    3            ANS     (answer delimiter; decoding starts after it)
    4            EOS     (end of answer)
    5            NOORD   (query has no ordinal constraint)
    6..13        ORD1..ORD8  (ordinal constraint: "the value in doc #i")
    14..15       reserved
    16..79       K0..K63   key tokens
    80..143      V0..V63   value tokens
    144..255     F0..F111  filler tokens

Task. Each sample has D documents of ``doc_len`` tokens. A document is
``[BOS, content...]`` where the content embeds (key, value) fact pairs in
filler noise. The query is a fixed 5-token frame

    [QUERY, ord, k1, k2_or_PAD, ANS]

and the gold answer is 1..2 value tokens followed by EOS:

  * single lookup      — ``ord = NOORD``, k2 = PAD, answer = value of k1
  * double lookup      — ``ord = NOORD``, answer = value(k1), value(k2)
  * ordinal lookup     — ``ord = ORDi``; k1 appears in *several* documents
    with different values and the answer is the one in document i. This is
    the position-critical case: with independently-prefilled (RoPE-local)
    KV caches the ordinal is unrecoverable, which reproduces the paper's
    "Reuse" collapse.
  * 2-hop lookup       — doc A holds (k1 -> Km) where Km is a *key* token,
    doc B holds (Km -> v); answer = v.
  * consensus lookup   — the (k1 -> v) fact appears verbatim in >=2
    documents ("inter-document consensus", §3.1 of the paper).
"""

# --- special tokens ---------------------------------------------------------
PAD = 0
BOS = 1
QUERY = 2
ANS = 3
EOS = 4
NOORD = 5
ORD_BASE = 6  # ORD1 = 6 ... ORD8 = 13
MAX_ORD = 8

KEY_BASE = 16
N_KEYS = 64
VAL_BASE = 80
N_VALS = 64
FILLER_BASE = 144
N_FILLERS = 112
VOCAB = 256

QUERY_LEN = 5  # [QUERY, ord, k1, k2, ANS]
ANSWER_MAX = 4  # up to 2 values + EOS (+ pad slack)


def key_tok(i: int) -> int:
    assert 0 <= i < N_KEYS
    return KEY_BASE + i


def val_tok(i: int) -> int:
    assert 0 <= i < N_VALS
    return VAL_BASE + i


def filler_tok(i: int) -> int:
    assert 0 <= i < N_FILLERS
    return FILLER_BASE + i


def ord_tok(i: int) -> int:
    """1-based document ordinal token."""
    assert 1 <= i <= MAX_ORD
    return ORD_BASE + i - 1


def is_value(tok: int) -> bool:
    return VAL_BASE <= tok < VAL_BASE + N_VALS


# --- model / serving profiles ----------------------------------------------
# A profile pins every static shape the AOT artifacts need. ``tiny`` exists
# for fast CI (untrained weights, shape-level tests); ``s4`` is the main
# trained model; ``m6`` is the second, larger model for Table 3/4's
# two-model comparison.

class Profile:
    def __init__(self, name, n_layers, d_model, n_heads, head_dim, d_ff,
                 n_docs, doc_len, block_size, init_blocks, local_blocks,
                 sel_cap_blocks, stable_layers, rope_theta=10000.0,
                 decode_lanes=4):
        self.name = name
        self.n_layers = n_layers
        self.d_model = d_model
        self.n_heads = n_heads
        self.head_dim = head_dim
        self.d_ff = d_ff
        self.vocab = VOCAB
        self.n_docs = n_docs
        self.doc_len = doc_len            # includes the leading BOS
        self.block_size = block_size
        self.init_blocks = init_blocks    # blocks kept at full resolution (head)
        self.local_blocks = local_blocks  # blocks kept at full resolution (tail)
        self.sel_cap_blocks = sel_cap_blocks  # max selected middle blocks, total
        self.stable_layers = stable_layers    # N*: trailing layers used in Eq. 3
        self.rope_theta = rope_theta
        # lane count of the batched decode entry points
        # (decode_{sparse,full}_batched): one fused serving round packs up
        # to this many sequences into a single XLA execution. Lanes are
        # unrolled at lowering time, so keep this small.
        self.decode_lanes = decode_lanes

    # ---- derived shapes -----------------------------------------------
    @property
    def blocks_per_doc(self):
        assert self.doc_len % self.block_size == 0
        return self.doc_len // self.block_size

    @property
    def ctx_len(self):
        return self.n_docs * self.doc_len

    @property
    def full_len(self):
        """prefill_full / decode_full static length (docs + query + answers)."""
        return self.ctx_len + QUERY_LEN + ANSWER_MAX

    @property
    def fixed_blocks_per_doc(self):
        return self.init_blocks + self.local_blocks

    @property
    def sparse_kv_len(self):
        """Static sparse-buffer KV capacity (init/local + selected blocks)."""
        fixed = self.n_docs * self.fixed_blocks_per_doc * self.block_size
        return fixed + self.sel_cap_blocks * self.block_size

    @property
    def sparse_len(self):
        """decode_sparse / recompute buffer length (kv + query + answers)."""
        return self.sparse_kv_len + QUERY_LEN + ANSWER_MAX

    @property
    def comp_len(self):
        """query_embed compressed-cache length: init+local blocks of every doc."""
        return self.n_docs * self.fixed_blocks_per_doc * self.block_size

    @property
    def total_blocks(self):
        return self.n_docs * self.blocks_per_doc

    def as_dict(self):
        return {
            "name": self.name,
            "n_layers": self.n_layers,
            "d_model": self.d_model,
            "n_heads": self.n_heads,
            "head_dim": self.head_dim,
            "d_ff": self.d_ff,
            "vocab": self.vocab,
            "n_docs": self.n_docs,
            "doc_len": self.doc_len,
            "block_size": self.block_size,
            "init_blocks": self.init_blocks,
            "local_blocks": self.local_blocks,
            "sel_cap_blocks": self.sel_cap_blocks,
            "stable_layers": self.stable_layers,
            "rope_theta": self.rope_theta,
            "query_len": QUERY_LEN,
            "answer_max": ANSWER_MAX,
            "ctx_len": self.ctx_len,
            "full_len": self.full_len,
            "sparse_kv_len": self.sparse_kv_len,
            "sparse_len": self.sparse_len,
            "comp_len": self.comp_len,
            "blocks_per_doc": self.blocks_per_doc,
            "decode_lanes": self.decode_lanes,
        }


PROFILES = {
    # CI profile: 2 layers, untrained, small shapes. Integration tests only.
    "tiny": Profile("tiny", n_layers=2, d_model=48, n_heads=2, head_dim=24,
                    d_ff=96, n_docs=2, doc_len=32, block_size=8,
                    init_blocks=1, local_blocks=1, sel_cap_blocks=2,
                    stable_layers=1),
    # Main trained model ("Qwen2.5-3B stand-in"): 4 layers, d=96.
    # Geometry: 4 docs x 64 tokens, blocks of 4 (16 blocks/doc) keeps the
    # paper's block ratios (1 init + 1 local = 12.5% fixed) at a context
    # length a CPU-trained model can master.
    "s4": Profile("s4", n_layers=4, d_model=96, n_heads=4, head_dim=24,
                  d_ff=256, n_docs=4, doc_len=32, block_size=4,
                  init_blocks=1, local_blocks=1, sel_cap_blocks=4,
                  stable_layers=2),
    # Second trained model ("Llama-3.1-8B stand-in"): 6 layers, d=128.
    "m6": Profile("m6", n_layers=6, d_model=128, n_heads=4, head_dim=32,
                  d_ff=320, n_docs=4, doc_len=32, block_size=4,
                  init_blocks=1, local_blocks=1, sel_cap_blocks=4,
                  stable_layers=2),
    # Ratio profile: longer documents at the paper's block:doc ratio for
    # the *structural* Table-1 sequence/recompute ratio measurement
    # (quality-free; weights untrained). 16 blocks/doc -> 12.5% fixed
    # floor + dynamic selection lands near the paper's ~15%.
    "x16": Profile("x16", n_layers=2, d_model=48, n_heads=2, head_dim=24,
                   d_ff=96, n_docs=4, doc_len=256, block_size=16,
                   init_blocks=1, local_blocks=1, sel_cap_blocks=8,
                   stable_layers=1),
}

# Dataset profiles substituting LongBench (see module docstring + DESIGN.md).
# Fractions: (single, double, ordinal, twohop); consensus_rate applies to
# single lookups; distractor_keys adds same-key-different-value conflicts
# (only for ordinal queries, where the ordinal disambiguates).
DATASETS = {
    "wiki2-sim": dict(single=0.2, double=0.1, ordinal=0.4, twohop=0.3,
                      consensus_rate=0.3, filler_entropy=1.0),
    "musique-sim": dict(single=0.1, double=0.1, ordinal=0.4, twohop=0.4,
                        consensus_rate=0.1, filler_entropy=1.0),
    "hotpot-sim": dict(single=0.3, double=0.2, ordinal=0.35, twohop=0.15,
                       consensus_rate=0.4, filler_entropy=1.0),
    "dureader-sim": dict(single=0.45, double=0.25, ordinal=0.3, twohop=0.0,
                         consensus_rate=0.3, filler_entropy=1.0),
}
