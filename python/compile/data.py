"""Synthetic multi-document QA data generator (LongBench stand-in).

Generates documents with embedded (key, value) facts and five query
families (single / double / ordinal / 2-hop / consensus) per the spec in
``taskspec.py``. Used for (a) training the tiny models, (b) emitting the
evaluation datasets consumed by the rust harness, (c) python-side tests.
"""
from __future__ import annotations

import json

import numpy as np

from . import taskspec as T


class Sample:
    __slots__ = ("docs", "query", "answer", "qtype")

    def __init__(self, docs, query, answer, qtype):
        self.docs = docs      # list[list[int]] each taskspec doc_len long
        self.query = query    # list[int] length QUERY_LEN
        self.answer = answer  # list[int] value tokens, no EOS
        self.qtype = qtype    # str

    def to_dict(self):
        return {"docs": self.docs, "query": self.query,
                "answer": self.answer, "qtype": self.qtype}


def _place_facts(rng: np.random.Generator, content_len: int, facts):
    """Place 2-token facts at non-overlapping positions in filler noise."""
    doc = [T.filler_tok(int(rng.integers(T.N_FILLERS)))
           for _ in range(content_len)]
    # choose fact slots on an even grid so facts never straddle each other
    n_slots = content_len // 2
    slots = rng.choice(n_slots, size=len(facts), replace=False)
    positions = []
    for (k, v), s in zip(facts, slots):
        p = int(s) * 2
        doc[p] = k
        doc[p + 1] = v
        positions.append(p)
    return doc, positions


class SampleGen:
    """Draws complete samples for one dataset profile."""

    def __init__(self, profile: T.Profile, dataset: str, seed: int):
        self.p = profile
        self.cfg = dict(T.DATASETS[dataset])
        self.dataset = dataset
        self.rng = np.random.default_rng(seed)
        # decoy facts per doc, bounded so the per-sample key permutation
        # (N_KEYS unique keys) never exhausts across all documents
        budget = (T.N_KEYS - 8) // profile.n_docs
        self.facts_per_doc = min(max(4, (profile.doc_len - 1) // 12),
                                 budget)

    # -- fact table construction ------------------------------------------
    def _draw_sample(self) -> Sample:
        rng = self.rng
        D = self.p.n_docs
        c = self.cfg
        r = rng.random()
        if r < c["single"]:
            qtype = "single"
        elif r < c["single"] + c["double"]:
            qtype = "double"
        elif r < c["single"] + c["double"] + c["ordinal"]:
            qtype = "ordinal"
        else:
            qtype = "twohop" if c["twohop"] > 0 else "single"

        # keys are globally partitioned per sample to control uniqueness
        keys = rng.permutation(T.N_KEYS)
        vals = rng.permutation(T.N_VALS)
        ki = iter(int(x) for x in keys)
        vi = iter(int(x) for x in vals)

        facts = [[] for _ in range(D)]  # per-doc list of (tok_k, tok_v)

        query = None
        answer = None

        if qtype == "single":
            k = next(ki)
            v = next(vi)
            consensus = rng.random() < c["consensus_rate"] and D >= 2
            docs_with = (sorted(rng.choice(D, size=2, replace=False).tolist())
                         if consensus else [int(rng.integers(D))])
            for d in docs_with:
                facts[d].append((T.key_tok(k), T.val_tok(v)))
            query = [T.QUERY, T.NOORD, T.key_tok(k), T.PAD, T.ANS]
            answer = [T.val_tok(v)]
            if consensus:
                qtype = "consensus"
        elif qtype == "double":
            k1, k2 = next(ki), next(ki)
            v1, v2 = next(vi), next(vi)
            facts[int(rng.integers(D))].append((T.key_tok(k1), T.val_tok(v1)))
            facts[int(rng.integers(D))].append((T.key_tok(k2), T.val_tok(v2)))
            query = [T.QUERY, T.NOORD, T.key_tok(k1), T.key_tok(k2), T.ANS]
            answer = [T.val_tok(v1), T.val_tok(v2)]
        elif qtype == "ordinal":
            # same key in every doc, different value per doc; ordinal picks one
            k = next(ki)
            per_doc_vals = [next(vi) for _ in range(D)]
            for d in range(D):
                facts[d].append((T.key_tok(k), T.val_tok(per_doc_vals[d])))
            target = int(rng.integers(D))
            query = [T.QUERY, T.ord_tok(target + 1), T.key_tok(k), T.PAD, T.ANS]
            answer = [T.val_tok(per_doc_vals[target])]
        else:  # twohop: (k1 -> Km) in doc a, (Km -> v) in doc b != a
            k1, km = next(ki), next(ki)
            v = next(vi)
            a, b = rng.choice(D, size=2, replace=False)
            facts[int(a)].append((T.key_tok(k1), T.key_tok(km)))
            facts[int(b)].append((T.key_tok(km), T.val_tok(v)))
            query = [T.QUERY, T.NOORD, T.key_tok(k1), T.PAD, T.ANS]
            answer = [T.val_tok(v)]

        # pad every doc with unique-key decoy facts so fact density is even
        for d in range(D):
            while len(facts[d]) < self.facts_per_doc:
                facts[d].append((T.key_tok(next(ki)), T.val_tok(next(vi))))

        docs = []
        for d in range(D):
            content, _ = _place_facts(self.rng, self.p.doc_len - 1, facts[d])
            docs.append([T.BOS] + content)
        return Sample(docs, query, answer, qtype)

    def sample(self) -> Sample:
        return self._draw_sample()

    def batch(self, n: int):
        return [self._draw_sample() for _ in range(n)]


# --- flat sequence assembly (training + full-recompute layout) -------------

def assemble_full(sample: Sample, profile: T.Profile, with_answer: bool):
    """[docs || query (|| answer EOS)] padded to profile.full_len.

    Returns (tokens, valid, loss_mask) as int32/float32 numpy arrays.
    loss_mask marks positions whose *target* (next token) is supervised:
    the answer tokens and the closing EOS.
    """
    seq = []
    for d in sample.docs:
        seq.extend(d)
    seq.extend(sample.query)
    ans_start = len(seq)  # first answer token goes here
    if with_answer:
        seq.extend(sample.answer)
        seq.append(T.EOS)
    L = profile.full_len
    assert len(seq) <= L, (len(seq), L)
    tokens = np.zeros(L, dtype=np.int32)
    tokens[: len(seq)] = seq
    valid = np.zeros(L, dtype=np.float32)
    valid[: len(seq)] = 1.0
    loss_mask = np.zeros(L, dtype=np.float32)
    if with_answer:
        # predicting token at position p uses logits at p-1
        for p in range(ans_start, ans_start + len(sample.answer) + 1):
            loss_mask[p - 1] = 1.0
    return tokens, valid, loss_mask, ans_start


def training_batch(gen: SampleGen, profile: T.Profile, batch: int):
    toks, valids, masks = [], [], []
    for s in gen.batch(batch):
        t, v, m, _ = assemble_full(s, profile, with_answer=True)
        toks.append(t)
        valids.append(v)
        masks.append(m)
    return (np.stack(toks), np.stack(valids), np.stack(masks))


# --- eval dataset emission ---------------------------------------------------

def write_eval_dataset(path: str, profile: T.Profile, dataset: str,
                       n_samples: int, seed: int):
    gen = SampleGen(profile, dataset, seed)
    samples = [s.to_dict() for s in gen.batch(n_samples)]
    payload = {
        "profile": profile.name,
        "dataset": dataset,
        "seed": seed,
        "samples": samples,
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    return len(samples)
