"""Build-time training of the stand-in models (see DESIGN.md §2).

Pure-JAX Adam (no optax in the image). Trains a ``taskspec.Profile``
model on the synthetic multi-document QA task with the *joint causal*
layout — exactly the layout the full-recompute baseline serves — and
reports exact-match accuracy per query family. Minutes on one CPU core;
``aot.py`` caches the resulting weights so this runs once.
"""
from __future__ import annotations

import functools
import json
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import taskspec as T

WEIGHTS_MAGIC = b"SAMKVW01"


# --------------------------------------------------------------------------
# weights (de)serialization — mirrored by rust/src/model/weights.rs
# --------------------------------------------------------------------------

def save_weights(path: str, cfg: T.Profile, params):
    header = {
        "profile": cfg.name,
        "arrays": [{"name": n, "shape": list(s)}
                   for (n, s) in M.param_specs(cfg)],
    }
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())


def load_weights(path: str, cfg: T.Profile):
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == WEIGHTS_MAGIC, magic
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        assert header["profile"] == cfg.name, (header["profile"], cfg.name)
        params = []
        for spec in header["arrays"]:
            n = int(np.prod(spec["shape"]))
            buf = f.read(4 * n)
            params.append(np.frombuffer(buf, "<f4").reshape(spec["shape"])
                          .copy())
    return params


# --------------------------------------------------------------------------
# loss / optimizer
# --------------------------------------------------------------------------

AUX_LM_WEIGHT = 0.25


def _loss(cfg, params, tokens, valid, loss_mask):
    """Answer-token loss plus a dense auxiliary LM loss.

    The answer loss alone (~2 supervised tokens/sample) is too sparse for
    the induction circuits the lookup task needs; the dense next-token
    loss over the context (where repeated facts across documents *are*
    predictable) provides the copying-head pressure.
    """
    logits = jax.vmap(lambda t, v: M.forward_logits(cfg, params, t, v))(
        tokens, valid)
    targets = jnp.roll(tokens, -1, axis=1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ans = jnp.sum(nll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)
    # dense mask: positions whose *target* is a real (valid) token
    dense = valid * jnp.roll(valid, -1, axis=1)
    dense = dense.at[:, -1].set(0.0)
    lm = jnp.sum(nll * dense) / jnp.maximum(jnp.sum(dense), 1.0)
    return ans + AUX_LM_WEIGHT * lm


def make_train_step(cfg: T.Profile, lr: float, total_steps: int = 0,
                    warmup: int = 100):
    """Adam with linear warmup and cosine decay to 20% of peak."""
    @jax.jit
    def step(params, m, v, t, tokens, valid, loss_mask):
        loss, grads = jax.value_and_grad(
            lambda p: _loss(cfg, p, tokens, valid, loss_mask))(params)
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads)]
        v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v, grads)]
        tt = t + 1
        sched = jnp.minimum(1.0, tt / max(warmup, 1))
        if total_steps:
            frac = jnp.clip((tt - warmup) / max(total_steps - warmup, 1),
                            0.0, 1.0)
            sched = sched * (0.2 + 0.8 * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        lr_t = lr * sched * jnp.sqrt(1 - b2 ** tt) / (1 - b1 ** tt)
        params = [p - lr_t * mi / (jnp.sqrt(vi) + eps)
                  for p, mi, vi in zip(params, m, v)]
        return params, m, v, tt, loss

    return step


# --------------------------------------------------------------------------
# greedy eval (full-recompute oracle path)
# --------------------------------------------------------------------------

def greedy_answer(cfg: T.Profile, params, sample: D.Sample, fwd=None):
    """Teacher-free greedy decode of up to ANSWER_MAX tokens."""
    tokens, valid, _, ans_start = D.assemble_full(sample, cfg,
                                                  with_answer=False)
    tokens = tokens.copy()
    valid = valid.copy()
    fwd = fwd or (lambda t, v: M.forward_logits(cfg, params, t, v))
    out = []
    cur = ans_start
    for _ in range(T.ANSWER_MAX):
        logits = fwd(jnp.asarray(tokens), jnp.asarray(valid))
        nxt = int(jnp.argmax(logits[cur - 1]))
        if nxt == T.EOS:
            break
        out.append(nxt)
        tokens[cur] = nxt
        valid[cur] = 1.0
        cur += 1
    return out


def evaluate(cfg: T.Profile, params, gen: D.SampleGen, n: int, fwd=None):
    """Exact-match rate overall and per query family."""
    hits, per = 0, {}
    fwd = fwd or jax.jit(
        lambda t, v: M.forward_logits(cfg, params, t, v))
    for s in gen.batch(n):
        got = greedy_answer(cfg, params, s, fwd)
        ok = got == s.answer
        hits += ok
        tot, h = per.get(s.qtype, (0, 0))
        per[s.qtype] = (tot + 1, h + ok)
    return hits / n, {k: (h / t if t else 0.0, t) for k, (t, h) in per.items()}


# --------------------------------------------------------------------------
# training driver
# --------------------------------------------------------------------------

# curriculum phase 1: mostly single lookups to bootstrap the induction
# circuit before the harder families join
CURRICULUM = dict(single=0.7, double=0.0, ordinal=0.3, twohop=0.0,
                  consensus_rate=0.2, filler_entropy=1.0)
CURRICULUM_FRAC = 0.3


def train(cfg: T.Profile, steps: int, batch: int = 8, lr: float = 1e-3,
          seed: int = 0, dataset: str = "hotpot-sim", log_every: int = 25,
          eval_every: int = 200, eval_n: int = 32):
    params = [jnp.asarray(p) for p in M.init_params(cfg, seed)]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    t = jnp.int32(0)
    gen = D.SampleGen(cfg, dataset, seed=seed + 1)
    easy_gen = D.SampleGen(cfg, dataset, seed=seed + 3)
    easy_gen.cfg = dict(CURRICULUM)
    eval_gen = D.SampleGen(cfg, dataset, seed=seed + 2)
    step = make_train_step(cfg, lr, total_steps=steps)
    t0 = time.time()
    for i in range(1, steps + 1):
        src = easy_gen if i < CURRICULUM_FRAC * steps else gen
        tokens, valid, mask = D.training_batch(src, cfg, batch)
        params, m, v, t, loss = step(params, m, v, t,
                                     jnp.asarray(tokens), jnp.asarray(valid),
                                     jnp.asarray(mask))
        if i % log_every == 0 or i == 1:
            print(f"[train:{cfg.name}] step {i}/{steps} "
                  f"loss {float(loss):.4f} ({time.time() - t0:.0f}s)",
                  flush=True)
        if eval_every and (i % eval_every == 0 or i == steps):
            em, per = evaluate(cfg, params, eval_gen, eval_n)
            per_s = " ".join(f"{k}={a:.2f}({n})" for k, (a, n) in
                             sorted(per.items()))
            print(f"[eval:{cfg.name}] step {i} EM {em:.3f} | {per_s}",
                  flush=True)
    return [np.asarray(p) for p in params]
