"""L2 model semantics: the serving entry points must agree with the joint
causal oracle wherever the paper's method guarantees equality."""
import numpy as np
import jax.numpy as jnp
import pytest

from compile import data as D
from compile import model as M
from compile import taskspec as T

P = T.PROFILES["tiny"]


@pytest.fixture(scope="module")
def params():
    return [jnp.asarray(p) for p in M.init_params(P, seed=3)]


@pytest.fixture(scope="module")
def sample():
    return D.SampleGen(P, "hotpot-sim", seed=11).sample()


def _full_tokens(sample):
    tokens, valid, _, ans_start = D.assemble_full(sample, P,
                                                  with_answer=False)
    return tokens, valid, ans_start


def test_param_specs_count(params):
    assert len(params) == M.n_params_arrays(P)
    for p, (_, shape) in zip(params, M.param_specs(P)):
        assert p.shape == shape


def test_prefill_doc_shapes(params, sample):
    kv, attn, qloc = M.prefill_doc(P, params, jnp.asarray(sample.docs[0]),
                                   jnp.int32(0))
    L, H, Dh, Ld = P.n_layers, P.n_heads, P.head_dim, P.doc_len
    assert kv.shape == (L, 2, H, Ld, Dh)
    assert attn.shape == (L, H, Ld, Ld)
    assert qloc.shape == (L, H, Dh)
    # attention rows are probability distributions over the causal prefix
    rows = np.asarray(attn).sum(-1)
    np.testing.assert_allclose(rows, np.ones_like(rows), rtol=1e-4)
    # strict causality: upper triangle is zero
    a = np.asarray(attn)
    for i in range(Ld - 1):
        assert np.abs(a[..., i, i + 1:]).max() < 1e-6


def test_first_doc_prefill_equals_joint_prefill(params, sample):
    """Doc 1 sits at positions 0..Ld-1 in the joint layout and attends only
    to itself, so independent prefill must reproduce the joint KV exactly."""
    tokens, valid, _ = _full_tokens(sample)
    (kv_full,) = M.prefill_full(P, params, jnp.asarray(tokens),
                                jnp.asarray(valid))
    kv_doc, _, _ = M.prefill_doc(P, params, jnp.asarray(sample.docs[0]),
                                 jnp.int32(0))
    np.testing.assert_allclose(np.asarray(kv_full)[:, :, :, :P.doc_len],
                               np.asarray(kv_doc), rtol=2e-4, atol=2e-4)


def test_second_doc_prefill_differs_from_joint(params, sample):
    """Doc 2's joint KV sees doc 1 (cross-attention) and different RoPE
    positions — the deficiency SamKV exists to repair."""
    tokens, valid, _ = _full_tokens(sample)
    (kv_full,) = M.prefill_full(P, params, jnp.asarray(tokens),
                                jnp.asarray(valid))
    kv_doc, _, _ = M.prefill_doc(P, params, jnp.asarray(sample.docs[1]),
                                 jnp.int32(0))
    joint = np.asarray(kv_full)[:, :, :, P.doc_len:2 * P.doc_len]
    indep = np.asarray(kv_doc)
    assert np.abs(joint - indep).max() > 1e-3


def test_recompute_all_equals_joint_prefill(params, sample):
    """Recomputing every slot at every layer from reused junk must yield
    exactly the joint prefill KV (rule-1/rule-2 degenerate case)."""
    tokens, valid, _ = _full_tokens(sample)
    lt = P.full_len
    (kv_full,) = M.prefill_full(P, params, jnp.asarray(tokens),
                                jnp.asarray(valid))
    kv_junk = jnp.zeros_like(kv_full)
    positions = jnp.arange(lt, dtype=jnp.int32)
    rec = jnp.ones((P.n_layers, lt), jnp.float32)
    (kv_out,) = M.recompute(P, params, jnp.asarray(tokens), positions,
                            kv_junk, rec, jnp.asarray(valid))
    got = np.asarray(kv_out) * np.asarray(valid)[None, None, None, :, None]
    want = np.asarray(kv_full) * np.asarray(valid)[None, None, None, :, None]
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_recompute_mask_zero_is_identity(params, sample):
    tokens, valid, _ = _full_tokens(sample)
    lt = P.full_len
    rng = np.random.default_rng(0)
    kv_in = jnp.asarray(rng.standard_normal(
        (P.n_layers, 2, P.n_heads, lt, P.head_dim)).astype(np.float32))
    rec = jnp.zeros((P.n_layers, lt), jnp.float32)
    (kv_out,) = M.recompute(P, params, jnp.asarray(tokens),
                            jnp.arange(lt, dtype=jnp.int32), kv_in, rec,
                            jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(kv_out), np.asarray(kv_in))


def test_decode_step_matches_forward_logits(params, sample):
    """Greedy next-token via decode_step over prefill_full KV must equal
    the training-forward argmax (teacher-forcing parity)."""
    tokens, valid, ans_start = _full_tokens(sample)
    (kv_full,) = M.prefill_full(P, params, jnp.asarray(tokens),
                                jnp.asarray(valid))
    logits_all = M.forward_logits(P, params, jnp.asarray(tokens),
                                  jnp.asarray(valid))
    # decode the token at ans_start given everything before it
    last = ans_start - 1  # ANS token position; kv buffer holds prefix
    kv_valid = (np.arange(P.full_len) < last).astype(np.float32)
    logits, k_new, v_new = M.decode_step(
        P, params, jnp.asarray(tokens[last]), jnp.int32(last),
        jnp.int32(last), kv_full, jnp.asarray(kv_valid))
    assert int(jnp.argmax(logits)) == int(jnp.argmax(logits_all[last]))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_all[last]),
                               rtol=5e-4, atol=5e-4)
    # the returned k/v must equal the prefill cache at that slot
    np.testing.assert_allclose(np.asarray(k_new),
                               np.asarray(kv_full)[:, 0, :, last], rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(v_new),
                               np.asarray(kv_full)[:, 1, :, last], rtol=2e-4,
                               atol=2e-4)


def test_decode_step_batched_matches_scalar(params, sample):
    """The lane-padded batched decode is the scalar entry replicated per
    lane (unrolled, not vmapped): live lanes must reproduce per-lane
    ``decode_step`` outputs and dead lanes must come back zeroed."""
    tokens, valid, ans_start = _full_tokens(sample)
    (kv_full,) = M.prefill_full(P, params, jnp.asarray(tokens),
                                jnp.asarray(valid))
    last = ans_start - 1
    kv_valid = (np.arange(P.full_len) < last).astype(np.float32)
    prev_valid = (np.arange(P.full_len) < last - 1).astype(np.float32)
    toks = jnp.asarray([tokens[last], tokens[last - 1], 0], jnp.int32)
    pos = jnp.asarray([last, last - 1, 0], jnp.int32)
    slot = jnp.asarray([last, last - 1, 0], jnp.int32)
    kv_b = jnp.stack([kv_full, kv_full, jnp.zeros_like(kv_full)])
    valid_b = jnp.stack([jnp.asarray(kv_valid), jnp.asarray(prev_valid),
                         jnp.zeros(P.full_len, jnp.float32)])
    live = jnp.asarray([1.0, 1.0, 0.0], jnp.float32)
    lg_b, kn_b, vn_b = M.decode_step_batched(P, params, toks, pos, slot,
                                             kv_b, valid_b, live)
    assert lg_b.shape == (3, P.vocab)
    assert kn_b.shape == (3, P.n_layers, P.n_heads, P.head_dim)
    assert vn_b.shape == kn_b.shape
    for b in range(2):
        lg, kn, vn = M.decode_step(P, params, toks[b], pos[b], slot[b],
                                   kv_b[b], valid_b[b])
        np.testing.assert_allclose(np.asarray(lg_b[b]), np.asarray(lg),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(kn_b[b]), np.asarray(kn),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vn_b[b]), np.asarray(vn),
                                   rtol=1e-6, atol=1e-6)
        assert int(jnp.argmax(lg_b[b])) == int(jnp.argmax(lg))
    # dead lane: outputs masked to zero regardless of padding contents
    assert np.abs(np.asarray(lg_b[2])).max() == 0.0
    assert np.abs(np.asarray(kn_b[2])).max() == 0.0
    assert np.abs(np.asarray(vn_b[2])).max() == 0.0


def test_batched_entrypoints_registered():
    eps = M.entrypoints(P)
    for name in ("decode_sparse_batched", "decode_full_batched"):
        assert name in eps
        _, arg_specs, needs_w = eps[name]
        assert needs_w
        assert arg_specs[0].shape == (P.decode_lanes,)
        assert arg_specs[3].shape[0] == P.decode_lanes
        assert arg_specs[5].shape == (P.decode_lanes,)  # live mask


def test_query_embed_shapes_and_pooling(params, sample):
    L, H, Dh, Lc = P.n_layers, P.n_heads, P.head_dim, P.comp_len
    rng = np.random.default_rng(5)
    comp_kv = jnp.asarray(rng.standard_normal(
        (L, 2, H, Lc, Dh)).astype(np.float32) * 0.1)
    comp_valid = jnp.ones(Lc, jnp.float32)
    q_pos = jnp.arange(P.ctx_len, P.ctx_len + T.QUERY_LEN, dtype=jnp.int32)
    q_que, q_kv = M.query_embed(P, params, jnp.asarray(sample.query),
                                comp_kv, comp_valid, q_pos)
    assert q_que.shape == (L, H, Dh)
    assert q_kv.shape == (L, 2, H, T.QUERY_LEN, Dh)
    # Q_que responds to the compressed cache (cross-attention is live)
    q_que2, _ = M.query_embed(P, params, jnp.asarray(sample.query),
                              comp_kv * 10.0, comp_valid, q_pos)
    assert np.abs(np.asarray(q_que) - np.asarray(q_que2)).max() > 1e-5


def test_score_blocks_prefers_matching_block(params):
    L, H, Dh, Ld = P.n_layers, P.n_heads, P.head_dim, P.doc_len
    q_hat = np.zeros((L, H, Dh), np.float32)
    q_hat[..., 0] = 1.0
    k = np.zeros((L, H, Ld, Dh), np.float32)
    k[:, :, :P.block_size, 0] = 2.0  # block 0 aligned with q_hat
    (scores,) = M.score_blocks(P, jnp.asarray(q_hat), jnp.asarray(k),
                               jnp.ones(Ld, jnp.float32))
    s = np.asarray(scores)
    assert s.shape == (L, Ld // P.block_size)
    assert (s[:, 0] > s[:, 1:].max(axis=1) + 0.5).all()
