"""Task generator invariants (the LongBench stand-in must be well-formed)."""
import numpy as np
import pytest

from compile import data as D
from compile import taskspec as T

P = T.PROFILES["tiny"]


def _gen(ds="hotpot-sim", seed=0):
    return D.SampleGen(P, ds, seed)


def test_doc_shape_and_bos():
    for s in _gen().batch(20):
        assert len(s.docs) == P.n_docs
        for d in s.docs:
            assert len(d) == P.doc_len
            assert d[0] == T.BOS


def test_query_frame():
    for s in _gen().batch(30):
        assert len(s.query) == T.QUERY_LEN
        assert s.query[0] == T.QUERY
        assert s.query[4] == T.ANS
        assert 1 <= len(s.answer) <= 2


def test_single_answer_is_in_some_doc():
    for s in _gen("dureader-sim", 1).batch(40):
        if s.qtype not in ("single", "consensus"):
            continue
        k, v = s.query[2], s.answer[0]
        found = any(
            d[i] == k and d[i + 1] == v
            for d in s.docs for i in range(len(d) - 1))
        assert found, (s.qtype, k, v)


def test_ordinal_is_position_critical():
    """Ordinal samples must have the key in *every* doc with distinct values
    — content alone cannot resolve the answer."""
    seen = 0
    for s in _gen("wiki2-sim", 2).batch(60):
        if s.qtype != "ordinal":
            continue
        seen += 1
        k = s.query[2]
        ordv = s.query[1] - T.ORD_BASE  # 0-based doc index
        vals = []
        for d in s.docs:
            hit = [d[i + 1] for i in range(len(d) - 1) if d[i] == k]
            assert len(hit) == 1
            vals.append(hit[0])
        assert len(set(vals)) == len(vals), "values must differ per doc"
        assert s.answer == [vals[ordv]]
    assert seen >= 5


def test_twohop_chain_exists():
    gen = D.SampleGen(T.PROFILES["s4"], "musique-sim", 3)
    seen = 0
    for s in gen.batch(60):
        if s.qtype != "twohop":
            continue
        seen += 1
        k1 = s.query[2]
        # hop 1: k1 -> km somewhere
        kms = [d[i + 1] for d in s.docs for i in range(len(d) - 1)
               if d[i] == k1]
        assert len(kms) == 1
        km = kms[0]
        assert T.KEY_BASE <= km < T.KEY_BASE + T.N_KEYS
        # hop 2: km -> answer value
        vs = [d[i + 1] for d in s.docs for i in range(len(d) - 1)
              if d[i] == km]
        assert s.answer[0] in vs
    assert seen >= 5


def test_consensus_duplicated():
    seen = 0
    for s in _gen("hotpot-sim", 4).batch(80):
        if s.qtype != "consensus":
            continue
        seen += 1
        k, v = s.query[2], s.answer[0]
        n_docs_with = sum(
            any(d[i] == k and d[i + 1] == v for i in range(len(d) - 1))
            for d in s.docs)
        assert n_docs_with >= 2
    assert seen >= 3


def test_assemble_full_layout():
    s = _gen().sample()
    tokens, valid, mask, ans_start = D.assemble_full(s, P, with_answer=True)
    assert tokens.shape == (P.full_len,)
    assert ans_start == P.ctx_len + T.QUERY_LEN
    assert tokens[ans_start - 1] == T.ANS
    n = len(s.answer)
    assert list(tokens[ans_start:ans_start + n]) == s.answer
    assert tokens[ans_start + n] == T.EOS
    # loss mask supervises exactly answer+EOS predictions
    assert mask.sum() == n + 1
    assert mask[ans_start - 1] == 1.0
    assert valid[:ans_start + n + 1].all()
    assert not valid[ans_start + n + 1:].any()


def test_determinism():
    a = [s.to_dict() for s in _gen(seed=9).batch(5)]
    b = [s.to_dict() for s in _gen(seed=9).batch(5)]
    assert a == b


def test_dataset_mixture_fractions():
    gen = _gen("musique-sim", 7)
    types = [s.qtype for s in gen.batch(300)]
    frac_2hop = types.count("twohop") / len(types)
    assert 0.25 < frac_2hop < 0.55
    assert types.count("ordinal") / len(types) > 0.25
