"""Weights serialization roundtrip (format shared with rust)."""
import numpy as np

from compile import model as M
from compile import taskspec as T
from compile import train as TR

P = T.PROFILES["tiny"]


def test_roundtrip(tmp_path):
    params = M.init_params(P, seed=42)
    path = str(tmp_path / "w.bin")
    TR.save_weights(path, P, params)
    loaded = TR.load_weights(path, P)
    assert len(loaded) == len(params)
    for a, b in zip(params, loaded):
        np.testing.assert_array_equal(a, b)


def test_header_is_json_prefixed(tmp_path):
    import json
    import struct
    params = M.init_params(P, seed=0)
    path = str(tmp_path / "w.bin")
    TR.save_weights(path, P, params)
    with open(path, "rb") as f:
        assert f.read(8) == TR.WEIGHTS_MAGIC
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
    assert header["profile"] == "tiny"
    assert [tuple(a["shape"]) for a in header["arrays"]] == \
        [s for _, s in M.param_specs(P)]


def test_train_step_decreases_loss():
    """Two gradient steps on a fixed batch must reduce the loss."""
    import jax.numpy as jnp
    from compile import data as D
    cfg = P
    params = [jnp.asarray(p) for p in M.init_params(cfg, 1)]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    t = jnp.int32(0)
    gen = D.SampleGen(cfg, "hotpot-sim", seed=5)
    tokens, valid, mask = D.training_batch(gen, cfg, 4)
    step = TR.make_train_step(cfg, lr=1e-3)
    losses = []
    for _ in range(3):
        params, m, v, t, loss = step(params, m, v, t, jnp.asarray(tokens),
                                     jnp.asarray(valid), jnp.asarray(mask))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
