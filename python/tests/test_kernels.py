"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_flash_attention, block_score
from compile.kernels.ref import masked_attention_ref, block_score_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# masked_flash_attention
# ---------------------------------------------------------------------------

@given(
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([8, 16, 48, 57, 137, 160]),
    head_dim=st.sampled_from([8, 16, 24, 32]),
    n_valid=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_flash_attention_matches_ref(heads, seq, head_dim, n_valid, seed):
    rng = np.random.default_rng(seed)
    n_valid = min(n_valid, seq)
    q = _rand(rng, heads, head_dim)
    k = _rand(rng, heads, seq, head_dim)
    v = _rand(rng, heads, seq, head_dim)
    valid = np.zeros(seq, np.float32)
    idx = rng.choice(seq, size=n_valid, replace=False)
    valid[idx] = 1.0
    got = np.asarray(masked_flash_attention(q, k, v, valid))
    ref = np.asarray(masked_attention_ref(q, k, v, valid))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


@given(
    tile=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_flash_attention_tile_invariance(tile, seed):
    """The tile size is a schedule choice — results must not depend on it."""
    rng = np.random.default_rng(seed)
    q, k, v = _rand(rng, 2, 16), _rand(rng, 2, 40, 16), _rand(rng, 2, 40, 16)
    valid = (rng.random(40) < 0.7).astype(np.float32)
    valid[0] = 1.0
    a = np.asarray(masked_flash_attention(q, k, v, valid, tile=tile))
    b = np.asarray(masked_attention_ref(q, k, v, valid))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_attention_ignores_invalid_garbage():
    """Padding slots may contain arbitrary data (even huge values)."""
    rng = np.random.default_rng(0)
    q = _rand(rng, 2, 8)
    k = _rand(rng, 2, 32, 8)
    v = _rand(rng, 2, 32, 8)
    valid = np.concatenate([np.ones(10), np.zeros(22)]).astype(np.float32)
    base = np.asarray(masked_flash_attention(q, k, v, valid))
    k2, v2 = k.copy(), v.copy()
    k2[:, 10:] = 1e6
    v2[:, 10:] = -1e6
    poisoned = np.asarray(masked_flash_attention(q, k2, v2, valid))
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_flash_attention_single_valid_slot_returns_value():
    rng = np.random.default_rng(1)
    q = _rand(rng, 1, 8)
    k = _rand(rng, 1, 16, 8)
    v = _rand(rng, 1, 16, 8)
    valid = np.zeros(16, np.float32)
    valid[5] = 1.0
    out = np.asarray(masked_flash_attention(q, k, v, valid))
    np.testing.assert_allclose(out[0], v[0, 5], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# block_score
# ---------------------------------------------------------------------------

@given(
    heads=st.sampled_from([1, 2, 4]),
    n_blocks=st.sampled_from([2, 4, 16]),
    block_size=st.sampled_from([4, 8, 16]),
    head_dim=st.sampled_from([8, 24]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_score_matches_ref(heads, n_blocks, block_size, head_dim, seed):
    rng = np.random.default_rng(seed)
    seq = n_blocks * block_size
    q = _rand(rng, heads, head_dim)
    k = _rand(rng, heads, seq, head_dim)
    valid = (rng.random(seq) < 0.8).astype(np.float32)
    got = np.asarray(block_score(q, k, valid, block_size))
    ref = np.asarray(block_score_ref(q, k, valid, block_size))
    assert got.shape == (n_blocks,)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_block_score_scales_with_alignment():
    """A block whose keys align with q must outscore an orthogonal block."""
    heads, block, head_dim = 2, 8, 16
    q = np.zeros((heads, head_dim), np.float32)
    q[:, 0] = 1.0
    k = np.zeros((heads, 2 * block, head_dim), np.float32)
    k[:, :block, 0] = 3.0   # aligned block
    k[:, block:, 1] = 3.0   # orthogonal block
    valid = np.ones(2 * block, np.float32)
    s = np.asarray(block_score(q, k, valid, block))
    assert s[0] > s[1] + 1.0
