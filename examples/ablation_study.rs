//! Interactive ablation study: runs SamKV with each Table-4 switch
//! combination on one dataset and prints the accuracy/cost trade-off.
//!
//! ```sh
//! cargo run --release --example ablation_study -- --profile s4 --samples 12
//! ```
use samkv::bench::{ms, Table};
use samkv::bench::experiments as exp;
use samkv::cli::Args;
use samkv::config::{SamKvConfig, UpdateStrategy};
use samkv::eval::evaluate;
use samkv::policies::SamKvPolicy;

fn main() -> samkv::Result<()> {
    let args = Args::parse_env();
    let profile = args.get_str(
        "profile",
        if exp::load_model("s4").is_ok() { "s4" } else { "tiny" });
    let n = args.get::<usize>("samples", 12);
    let model = exp::load_model(&profile)?;
    let ds = exp::load_dataset(&model,
                               &args.get_str("dataset", "hotpot-sim"))?;
    println!("SamKV ablations on {} / {} (n={n})\n", profile, ds.dataset);

    let mut tbl = Table::new(&["selection", "pers-bias", "recompute",
                               "update", "F1", "TTFT", "plan ms",
                               "seq%", "rec%"]);
    for (sel, pb, rec, update) in [
        (false, false, false, UpdateStrategy::Fusion),
        (false, false, true, UpdateStrategy::Fusion),
        (true, false, false, UpdateStrategy::Fusion),
        (true, true, false, UpdateStrategy::Fusion),
        (true, false, true, UpdateStrategy::Fusion),
        (true, true, true, UpdateStrategy::Overwrite),
        (true, true, true, UpdateStrategy::Fusion),
    ] {
        let p = SamKvPolicy::new(SamKvConfig {
            selection: sel,
            pers_bias: pb,
            recompute: rec,
            update,
            ..SamKvConfig::default()
        });
        let r = evaluate(&model, &p, &ds, n)?;
        let b = |x: bool| if x { "yes" } else { "no" }.to_string();
        tbl.row(vec![
            b(sel), b(pb), b(rec),
            format!("{update:?}"),
            format!("{:.2}", r.f1),
            ms(r.mean_ttft_ms),
            format!("{:.3}", r.mean_plan_ms),
            format!("{:.1}", 100.0 * r.mean_seq_ratio),
            format!("{:.1}", 100.0 * r.mean_recompute_ratio),
        ]);
    }
    tbl.print();
    Ok(())
}
