//! Quickstart: load a model profile, serve one multi-document request
//! with SamKV through the staged serving protocol (plan → prefill_docs
//! → assemble → attend → decode_step), streaming tokens as they
//! decode, and print what each stage did.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
use std::io::Write;

use samkv::bench::experiments as exp;
use samkv::config::SamKvConfig;
use samkv::kvcache::EngineDocCache;
use samkv::policies::{ContextPolicy, FnSink, SamKvPolicy, ServeSession};
use samkv::tokenizer as tok;

fn main() -> samkv::Result<()> {
    // pick the best available profile
    let profile = ["s4", "tiny"]
        .iter()
        .find(|p| exp::load_model(p).is_ok())
        .expect("run `make artifacts` first");
    let model = exp::load_model(profile)?;
    println!("loaded profile `{}` ({} params, {} layers, d={})",
             model.name, model.n_params, model.cfg.n_layers,
             model.cfg.d_model);

    let ds = exp::load_dataset(&model, "hotpot-sim")?;
    let sample = &ds.samples[0];
    println!("\nquery: {}", tok::render(&sample.query));
    println!("gold answer: {}", tok::render(&sample.answer));

    let mut store = EngineDocCache::unbounded();
    let policy = SamKvPolicy::new(SamKvConfig::default());

    // stage 1 — pure planning (no model, no device)
    let mut session = ServeSession::new(&policy, &model.cfg, sample.clone());
    println!("\nplan: {} doc caches needed, buffer {:?}, \
              {} fixed spans, <= {} dynamic blocks, \
              ~{} tokens planned for recompute",
             session.plan().doc_hashes.len(), session.plan().buffer,
             session.plan().fixed_spans.len(),
             session.plan().dynamic_blocks,
             session.plan().planned_recompute_tokens);

    // stages 2-4 — document prefill, sparsify/recompute, query prefill
    session.prefill_docs(&model, &mut store)?;
    session.assemble(&model)?;
    session.attend(&model)?;

    // stage 5 — streaming decode: tokens print as they are generated
    print!("\nSamKV-fusion streams:");
    let mut sink = FnSink(|t: i32| {
        print!(" {}", tok::render(&[t]));
        let _ = std::io::stdout().flush();
    });
    while session.decode_step(&model, &mut sink)?.is_some() {}
    println!();

    let out = session.finish();
    println!("\nfinal answer        : {}", tok::render(&out.answer));
    println!("plan                : {:.3} ms", out.stats.plan_ms);
    println!("doc prefill         : {:.1} ms (warm: {})",
             out.stats.doc_prefill_ms, out.stats.cache_warm);
    println!("TTFT (assemble+attend+1st token): {:.1} ms",
             out.stats.ttft_ms);
    println!("decode              : {:.1} ms", out.stats.decode_ms);
    println!("sequence ratio      : {:.1}% of the joint context",
             100.0 * out.stats.seq_ratio);
    println!("recompute ratio     : {:.1}% of context tokens",
             100.0 * out.stats.recompute_ratio);
    println!("KV loaded           : {} KiB", out.stats.kv_bytes / 1024);

    // the legacy blocking entry point still works and is
    // token-identical — it is a default method over the same stages
    let blocking = policy.run(&model, &mut store, sample)?;
    assert_eq!(blocking.answer, out.answer);
    println!("\n`run()` (blocking, warm cache) agreed: {}",
             tok::render(&blocking.answer));
    Ok(())
}
