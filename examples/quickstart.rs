//! Quickstart: load a model profile, serve one multi-document request
//! with SamKV, and print what the pipeline did.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
use samkv::bench::experiments as exp;
use samkv::config::SamKvConfig;
use samkv::kvcache::CacheStore;
use samkv::policies::{ContextPolicy, SamKvPolicy};
use samkv::tokenizer as tok;

fn main() -> samkv::Result<()> {
    // pick the best available profile
    let profile = ["s4", "tiny"]
        .iter()
        .find(|p| exp::load_model(p).is_ok())
        .expect("run `make artifacts` first");
    let model = exp::load_model(profile)?;
    println!("loaded profile `{}` ({} params, {} layers, d={})",
             model.name, model.n_params, model.cfg.n_layers,
             model.cfg.d_model);

    let ds = exp::load_dataset(&model, "hotpot-sim")?;
    let sample = &ds.samples[0];
    println!("\nquery: {}", tok::render(&sample.query));
    println!("gold answer: {}", tok::render(&sample.answer));

    let mut store = CacheStore::unbounded();
    let policy = SamKvPolicy::new(SamKvConfig::default());
    let out = policy.run(&model, &mut store, sample)?;

    println!("\nSamKV-fusion answered: {}", tok::render(&out.answer));
    println!("sequence ratio     : {:.1}% of the joint context",
             100.0 * out.stats.seq_ratio);
    println!("recompute ratio    : {:.1}% of context tokens",
             100.0 * out.stats.recompute_ratio);
    println!("KV loaded          : {} KiB", out.stats.kv_bytes / 1024);
    println!("TTFT               : {:.1} ms (docs cached: {})",
             out.stats.ttft_ms, out.stats.cache_warm);
    Ok(())
}
