//! Appendix-A attention analytics walkthrough: per-block power-law fits
//! (Fig. 7) and layer-stability scores (Fig. 8) on real prefill
//! attention maps, plus the dynamic Top-P values they induce (Eq. 2/3).
//!
//! ```sh
//! cargo run --release --example attention_analysis -- --profile s4
//! ```
use samkv::attention::{analyze_doc, layer_stability_scores,
                       select_stable_layers};
use samkv::bench::experiments as exp;
use samkv::bench::Table;
use samkv::cli::Args;
use samkv::kvcache::EngineDocCache;
use samkv::sparse::{block_scores_host, topp_select};

fn main() -> samkv::Result<()> {
    let args = Args::parse_env();
    let profile = args.get_str(
        "profile",
        if exp::load_model("s4").is_ok() { "s4" } else { "tiny" });
    let model = exp::load_model(&profile)?;
    let cfg = model.cfg.clone();
    let ds = exp::load_dataset(&model,
                               &args.get_str("dataset", "hotpot-sim"))?;
    let mut store = EngineDocCache::unbounded();

    // one document in depth
    let sample = &ds.samples[0];
    let (entry, _) = store.get_or_prefill(&model, &sample.docs[0])?;
    let ba = analyze_doc(&entry.attn, &cfg, 3.0);
    let l = cfg.n_layers - 1;
    println!("doc 0, layer {l}: per-block dual scores (A.1)\n");
    let mut tbl = Table::new(&["block", "rep token", "alpha",
                               "mean recv", "rank"]);
    for b in 0..cfg.blocks_per_doc {
        tbl.row(vec![
            format!("{b}"),
            format!("{}", ba.rep_token[l][b]),
            format!("{:.3}", ba.alpha[l][b]),
            format!("{:.4}", ba.mean_received[l][b]),
            format!("{}", ba.importance_rank[l][b]),
        ]);
    }
    tbl.print();
    println!("max-importance middle block: {:?}; max-unimportance: {:?}",
             ba.max_middle_block(&cfg, l), ba.min_middle_block(&cfg, l));

    // Eq. 2/3 Top-P with a neutral query direction
    let stable: Vec<usize> =
        (cfg.stable_layer_start()..cfg.n_layers).collect();
    let q = entry.q_local.clone();
    let per_layer: Vec<Vec<f32>> = stable
        .iter()
        .map(|&sl| block_scores_host(&q, &entry.kv, &cfg, sl))
        .collect();
    let sel = topp_select(&cfg, &per_layer, &stable, &ba);
    println!("\nEq.2 per-layer P: {:?}", sel.p_per_layer);
    println!("Eq.3 consolidated P = {:.3} -> picked middle blocks {:?}",
             sel.p, sel.picked);

    // Fig. 8 stability across many documents
    let mut analyses = Vec::new();
    for s in ds.samples.iter().take(8) {
        for d in &s.docs {
            let (e, _) = store.get_or_prefill(&model, d)?;
            analyses.push(analyze_doc(&e.attn, &cfg, 3.0));
        }
    }
    let refs: Vec<_> = analyses.iter().collect();
    let scores = layer_stability_scores(&refs, 1.5);
    println!("\nlayer stability scores (Fig. 8): {:?}", scores);
    println!("selected N* (k={}): {:?}", cfg.stable_layers,
             select_stable_layers(&scores, cfg.stable_layers));
    Ok(())
}
