//! End-to-end RAG serving driver (the DESIGN.md §3 system experiment):
//! spawns the full coordinator stack (engine thread + router + TCP
//! server), drives batched requests with recurring document sets over a
//! real client connection, and reports latency/throughput — proving all
//! three layers compose (rust coordinator -> PJRT artifacts -> Pallas
//! kernel decode path).
//!
//! ```sh
//! cargo run --release --example rag_serving -- --profile s4 --requests 24
//! ```
use std::sync::{mpsc, Arc};
use std::thread;

use samkv::bench::experiments as exp;
use samkv::cli::Args;
use samkv::config::ServingConfig;
use samkv::coordinator::{Engine, Router};
use samkv::kvcache::HostDocCache;
use samkv::metrics::Metrics;
use samkv::rng::Rng;
use samkv::runtime::artifacts_dir;
use samkv::server::{Client, Server};
use samkv::workload::synthetic_sample;

fn main() -> samkv::Result<()> {
    let args = Args::parse_env();
    let profile = args.get_str(
        "profile",
        if exp::load_model("s4").is_ok() { "s4" } else { "tiny" });
    let n_requests = args.get::<usize>("requests", 24);
    let n_unique = args.get::<usize>("unique", 6);
    let policy = args.get_str("policy", "SamKV-fusion");

    let metrics = Arc::new(Metrics::new());
    let cfg = ServingConfig { profile: profile.clone(),
                              ..ServingConfig::default() };
    let host = Arc::new(HostDocCache::unbounded());
    let router = Arc::new(Router::new(1));
    let engine = Engine::spawn(0, artifacts_dir(), cfg, policy.clone(),
                               Arc::clone(&metrics), host,
                               Some(router.residency_handle(0)))?;
    let server = Server::with_router(vec![engine.handle()],
                                     Arc::clone(&metrics), router);
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            let _ = port_tx.send(p);
        })
    });
    let port = port_rx.recv().expect("server bound");
    println!("serving profile `{profile}` on 127.0.0.1:{port} \
              (policy {policy})");

    let model = exp::load_model(&profile)?;
    let mut rng = Rng::new(7);
    let pool: Vec<_> = (0..n_unique)
        .map(|_| synthetic_sample(&model.cfg, &mut rng))
        .collect();

    let mut client = Client::connect(&format!("127.0.0.1:{port}"))?;
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let s = &pool[i % n_unique];
        let resp = client.request(&s.docs, &s.query, &policy)?;
        if i < 3 || i + 1 == n_requests {
            println!(
                "req {i:>3}: ttft {:.1}ms seq {:.1}% warm {}",
                resp.get("ttft_ms").unwrap().as_f64().unwrap(),
                100.0 * resp.get("seq_ratio").unwrap().as_f64().unwrap(),
                resp.get("cache_warm").unwrap().as_bool().unwrap(),
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // streaming: tokens arrive over the wire as they decode
    let s = &pool[0];
    print!("\nstreaming demo:");
    let resp = client.request_stream(&s.docs, &s.query, &policy, |t| {
        print!(" {t}");
        let _ = std::io::Write::flush(&mut std::io::stdout());
    })?;
    println!("\nstreamed request: ttft {:.1}ms (plan {:.3}ms, \
              doc prefill {:.1}ms, warm {})",
             resp.get("ttft_ms").unwrap().as_f64().unwrap(),
             resp.get("plan_ms").unwrap().as_f64().unwrap(),
             resp.get("doc_prefill_ms").unwrap().as_f64().unwrap(),
             resp.get("cache_warm").unwrap().as_bool().unwrap());

    println!("\n{}", metrics.report());
    println!("{} requests in {:.1}s -> {:.2} req/s", n_requests, wall,
             n_requests as f64 / wall);

    client.shutdown()?;
    srv.join().unwrap()?;
    Ok(())
}
