//! Fault-injection integration over the tiny artifacts: a seeded
//! [`samkv::faultinject::FaultPlan`] kills an engine's decode thread
//! mid-round and corrupts disk-tier block records, and the self-healing
//! machinery must keep every request terminal — token-identical answers
//! on retry success, structured errors otherwise, zero hangs. Also
//! exercises the disk tier's circuit breaker end to end: open at the
//! consecutive-error threshold, short-circuit while open, re-close via
//! a successful half-open probe.
//!
//! Tests no-op when artifacts aren't built.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use samkv::config::{DiskWriteback, ServingConfig};
use samkv::coordinator::{Engine, Router, ServeRequest, ServeResponse};
use samkv::faultinject::{FaultPlan, FaultSite};
use samkv::kvcache::{
    doc_hash, DiskDocCache, HostDocCache, KvBlockPool,
    DEFAULT_KV_BLOCK_TOKENS,
};
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::server::{Client, Server};
use samkv::workload::{Dataset, Sample};

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

fn cache_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("samkv-itest-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One single-engine serving stack over a disk cache dir (write-
/// through), optionally with a fault plan attached to the disk tier.
/// Serves the sample once; dropping the returns is a "restart".
fn serve_once(dir: &PathBuf, plan: Option<Arc<FaultPlan>>, sample: &Sample)
              -> (ServeResponse, Arc<Metrics>, Arc<DiskDocCache>) {
    let metrics = Arc::new(Metrics::new());
    let mut disk = DiskDocCache::open(dir, usize::MAX).unwrap();
    if let Some(p) = plan {
        disk = disk.with_faults(p);
    }
    let disk = Arc::new(disk);
    let host = Arc::new(
        HostDocCache::unbounded()
            .with_disk(Arc::clone(&disk), DiskWriteback::Through),
    );
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "Reuse".to_string(), Arc::clone(&metrics),
                               host, None)
        .unwrap();
    let resp = engine
        .handle()
        .serve(ServeRequest {
            id: 1,
            sample: sample.clone(),
            policy: String::new(),
            stream: false,
        })
        .unwrap();
    (resp, metrics, disk)
}

/// The headline self-healing path: engine 0's decode thread is killed
/// by the fault plan on its first decode round with a request in
/// flight. The server must mark it down, resubmit to the survivor, and
/// return a token-identical success; follow-up requests must route to
/// the survivor; the `cmd:metrics` wire must carry the fault counters.
#[test]
fn engine_kill_mid_round_retries_to_survivor() {
    let Some(ds) = ready() else { return };

    // find a sample that (a) routes to engine 0 on a fresh two-engine
    // router (affinity fold, loads tied) and (b) decodes more than one
    // token, so the round-2 kill lands with the session still active —
    // a clean single-engine stack supplies the baseline answer
    let base_metrics = Arc::new(Metrics::new());
    let baseline = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                                 "Reuse".to_string(),
                                 Arc::clone(&base_metrics),
                                 Arc::new(HostDocCache::unbounded()), None)
        .unwrap();
    let bh = baseline.handle();
    let mut victim = None;
    for attempt in 0i32..64 {
        let mut s =
            ds.samples[attempt as usize % ds.samples.len()].clone();
        for d in &mut s.docs {
            d[1] = samkv::tokenizer::filler_tok(
                attempt % samkv::tokenizer::N_FILLERS);
            d[2] = samkv::tokenizer::filler_tok(
                (attempt * 7 + 3) % samkv::tokenizer::N_FILLERS);
        }
        if Router::affinity_hash(&s) % 2 != 0 {
            continue;
        }
        let r = bh
            .serve(ServeRequest { id: 1000 + attempt as u64,
                                  sample: s.clone(),
                                  policy: String::new(), stream: false })
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        if r.answer.len() >= 2 {
            victim = Some((s, r.answer));
            break;
        }
    }
    drop(baseline);
    let (sample, base_answer) = victim
        .expect("no engine-0-affine multi-token sample in 64 tries");

    // two-engine chaos stack: kill engine 0 on its second scheduler
    // round (the first round of its first admitted wave is round 2)
    let plan = Arc::new(
        FaultPlan::parse("seed=11;engine_kill:engine=0:after=1").unwrap());
    let metrics = Arc::new(Metrics::new());
    let cfg = ServingConfig {
        fault_plan: Some(Arc::clone(&plan)),
        request_timeout_ms: 60_000,
        retry_backoff_ms: 5,
        ..tiny_cfg()
    };
    let host = Arc::new(HostDocCache::unbounded());
    let router = Arc::new(Router::new(2));
    let engines: Vec<Engine> = (0..2)
        .map(|i| {
            Engine::spawn(i, artifacts_dir(), cfg.clone(),
                          "Reuse".to_string(), Arc::clone(&metrics),
                          Arc::clone(&host),
                          Some(router.residency_handle(i)))
                .unwrap()
        })
        .collect();
    let handles = engines.iter().map(|e| e.handle()).collect();
    let server =
        Server::with_router(handles, Arc::clone(&metrics),
                            Arc::clone(&router))
            .with_resilience(cfg.request_retries, cfg.retry_backoff_ms,
                             cfg.request_timeout_ms)
            .with_faults(Some(Arc::clone(&plan)));
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());

    // all client traffic runs behind a watchdog: a request that never
    // produces a terminal line is the one failure mode this subsystem
    // exists to rule out
    let extra: Vec<Sample> = (0..5)
        .map(|i| ds.samples[i % ds.samples.len()].clone())
        .collect();
    let (done_tx, done_rx) = mpsc::channel();
    {
        let (addr, sample) = (addr.clone(), sample.clone());
        thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let first = client
                .request(&sample.docs, &sample.query, "Reuse")
                .unwrap();
            let rest: Vec<_> = extra
                .iter()
                .map(|s| {
                    client.request(&s.docs, &s.query, "Reuse").unwrap()
                })
                .collect();
            let m = client.metrics().unwrap();
            done_tx.send((first, rest, m)).unwrap();
        });
    }
    let (first, rest, m) = done_rx
        .recv_timeout(Duration::from_secs(180))
        .expect("chaos serving hung: no terminal responses within 180s");

    assert!(first.get("error").is_none(),
            "the killed-and-retried request must succeed: {first}");
    assert_eq!(first.get("answer").unwrap().i32_vec().unwrap(),
               base_answer,
               "retry success must be token-identical to the clean \
                baseline");
    for r in &rest {
        assert!(r.get("error").is_none(),
                "post-kill requests must succeed on the survivor: {r}");
    }
    assert_eq!(plan.injected(FaultSite::EngineKill), 1);
    assert!(router.is_down(0),
            "the router must stop placing on the dead engine");
    assert!(!router.is_down(1));
    assert!(!engines[0].handle().is_alive());
    assert!(engines[1].handle().is_alive());
    assert!(metrics.retries.load(Ordering::Relaxed) >= 1,
            "the failed attempt must be counted as a retry");
    assert!(metrics.retry_successes.load(Ordering::Relaxed) >= 1,
            "the resubmission must be counted as a retry success");
    assert!(metrics.engine_down_events.load(Ordering::Relaxed) >= 1);

    // the wire carries the fault counters
    let f = m.get("faults").expect("cmd:metrics must carry `faults`");
    assert_eq!(f.get("engine_kill").unwrap().as_i64(), Some(1), "{m}");
    assert!(f.get("injected").unwrap().as_i64().unwrap() >= 1);
    assert!(f.get("retry_successes").unwrap().as_i64().unwrap() >= 1);
    assert!(f.get("engine_down_events").unwrap().as_i64().unwrap() >= 1);
    assert_eq!(f.get("engines_down").unwrap().as_i64(), Some(1));
    assert!(m.get("report").unwrap().as_str().unwrap()
        .contains("faults(injected="),
            "report must carry the faults segment");

    Client::connect(&addr).unwrap().shutdown().unwrap();
    srv.join().unwrap().unwrap();
    drop(engines);
}

/// An engine whose decode thread died before any request arrived must
/// fail requests promptly with structured errors — never hang the
/// submitter — and flip its `is_alive` flag for the server's pre-check.
#[test]
fn dead_engine_fails_requests_promptly() {
    let Some(ds) = ready() else { return };
    let plan =
        Arc::new(FaultPlan::parse("seed=3;engine_kill:engine=0").unwrap());
    let cfg = ServingConfig { fault_plan: Some(Arc::clone(&plan)),
                              ..tiny_cfg() };
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::spawn(0, artifacts_dir(), cfg,
                               "Reuse".to_string(), Arc::clone(&metrics),
                               Arc::new(HostDocCache::unbounded()), None)
        .unwrap();
    let h = engine.handle();

    // the kill fires on the decode loop's first round, before any work
    let t0 = Instant::now();
    while h.is_alive() && t0.elapsed() < Duration::from_secs(30) {
        thread::sleep(Duration::from_millis(10));
    }
    assert!(!h.is_alive(), "injected kill must flip the alive flag");
    assert_eq!(plan.injected(FaultSite::EngineKill), 1);

    let (tx, rx) = mpsc::channel();
    let s = ds.samples[0].clone();
    thread::spawn(move || {
        let serve = |id, sample: &Sample| {
            h.serve(ServeRequest { id, sample: sample.clone(),
                                   policy: String::new(), stream: false })
                .map_err(|e| format!("{e:#}"))
        };
        let r1 = serve(1, &s);
        let r2 = serve(2, &s);
        tx.send((r1, r2)).unwrap();
    });
    let (r1, r2) = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("request against a dead engine hung");
    for r in [r1, r2] {
        match r {
            Ok(resp) => {
                let msg = resp.error
                    .expect("a dead engine must not answer");
                assert!(msg.contains("decode thread"), "{msg}");
            }
            Err(msg) => {
                assert!(msg.contains("engine closed")
                            || msg.contains("engine dropped reply"),
                        "{msg}");
            }
        }
    }
}

/// Breaker lifecycle on the disk tier, driven by injected read errors:
/// open at the consecutive-error threshold, short-circuit while open
/// (no device touch, no injection trial consumed), re-open on a failed
/// half-open probe, re-close on a successful one — which then serves
/// the entry.
#[test]
fn disk_breaker_opens_short_circuits_and_recloses() {
    let Some(ds) = ready() else { return };
    let dir = cache_dir("breaker");
    let sample = ds.samples[0].clone();
    {
        let (resp, _, disk) = serve_once(&dir, None, &sample);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(disk.stats().spills >= 1, "populate pass must spill");
    }

    let plan =
        Arc::new(FaultPlan::parse("seed=5;disk_read:count=4").unwrap());
    let disk = DiskDocCache::open(&dir, usize::MAX)
        .unwrap()
        .with_breaker(2, Duration::from_millis(300))
        .with_faults(Arc::clone(&plan));
    let pool = Arc::new(KvBlockPool::new(DEFAULT_KV_BLOCK_TOKENS));
    let doc = sample.docs[0].clone();
    let h = doc_hash(&doc);
    assert!(disk.contains(h), "populate pass must have persisted the doc");

    // two injected read errors trip the threshold-2 breaker
    assert!(disk.load(h, &doc, &pool).is_none());
    assert!(!disk.breaker_is_open(), "one error must not trip it");
    assert!(disk.load(h, &doc, &pool).is_none());
    assert!(disk.breaker_is_open(), "threshold-2 breaker must open");
    assert_eq!(disk.stats().breaker_opens, 1);

    // while open: answered as a miss without touching the device, so
    // no injection trial is consumed either
    assert!(disk.load(h, &doc, &pool).is_none());
    assert_eq!(disk.stats().breaker_short_circuits, 1);
    assert_eq!(plan.injected(FaultSite::DiskRead), 2);

    // failed half-open probes go straight back to open
    thread::sleep(Duration::from_millis(400));
    assert!(disk.load(h, &doc, &pool).is_none());
    assert_eq!(disk.stats().breaker_opens, 2, "failed probe re-opens");
    thread::sleep(Duration::from_millis(400));
    assert!(disk.load(h, &doc, &pool).is_none());
    assert_eq!(disk.stats().breaker_opens, 3);

    // injection budget exhausted: the next probe reads for real,
    // re-closes the breaker, and serves the entry
    thread::sleep(Duration::from_millis(400));
    assert!(disk.load(h, &doc, &pool).is_some(),
            "healthy probe must serve the entry");
    assert!(!disk.breaker_is_open());
    let st = disk.stats();
    assert_eq!(st.breaker_closes, 1);
    assert_eq!(st.io_errors, 4);
    assert_eq!(plan.injected(FaultSite::DiskRead), 4);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Injected block corruption on the write path must be caught by the
/// per-record checksums on the next cold read and heal through the
/// prefill fallback — token-identical answer, no error surfaced.
#[test]
fn injected_block_corruption_heals_on_restart() {
    let Some(ds) = ready() else { return };
    let dir = cache_dir("corrupt");
    let sample = ds.samples[0].clone();
    let plan = Arc::new(
        FaultPlan::parse("seed=9;corrupt_block:every=1").unwrap());

    let clean_answer = {
        let (resp, _, disk) =
            serve_once(&dir, Some(Arc::clone(&plan)), &sample);
        assert!(resp.error.is_none(),
                "corrupting spills must not fail the request: {:?}",
                resp.error);
        assert!(disk.stats().spills >= 1);
        assert!(plan.injected(FaultSite::CorruptBlock) >= 1,
                "every spill must have been corrupted");
        resp.answer
    };

    // restart over the poisoned dir: each file lost one block record;
    // reads must drop exactly those and the request must heal
    {
        let (resp, _, disk) = serve_once(&dir, None, &sample);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.answer, clean_answer,
                   "healed request must be token-identical");
        assert!(disk.stats().corrupt_blocks >= 1,
                "corrupted records must be detected, not served");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
