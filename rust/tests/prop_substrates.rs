//! Property-based tests over the substrates and the sparse-pipeline
//! invariants, driven by the seeded [`samkv::rng::Rng`] (no proptest in
//! the offline image — each property runs a few hundred random cases
//! with the failing seed printed by the assertion message).

use samkv::eval::token_f1;
use samkv::json::{self, Value};
use samkv::rng::Rng;
use samkv::tensor::{cosine, powerlaw_fit, Tensor};

const CASES: u64 = 200;

fn rand_value(rng: &mut Rng, depth: usize) -> Value {
    match if depth > 2 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.next_f32() < 0.5),
        2 => Value::Num((rng.next_f64() * 2e6 - 1e6).round() / 16.0),
        3 => {
            let n = rng.below(12);
            Value::Str(
                (0..n)
                    .map(|_| {
                        char::from_u32(32 + rng.below(90) as u32).unwrap()
                    })
                    .collect(),
            )
        }
        4 => Value::Arr(
            (0..rng.below(5)).map(|_| rand_value(rng, depth + 1)).collect(),
        ),
        _ => Value::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), rand_value(rng, depth + 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let v = rand_value(&mut rng, 0);
        let s = v.to_string();
        let back = json::parse(&s)
            .unwrap_or_else(|e| panic!("seed {seed}: parse failed {e}: {s}"));
        assert_eq!(v, back, "seed {seed}: {s}");
    }
}

#[test]
fn prop_f1_bounds_and_symmetries() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xf1);
        let n = 1 + rng.below(4);
        let m = 1 + rng.below(4);
        let pred: Vec<i32> =
            (0..n).map(|_| 80 + rng.below(8) as i32).collect();
        let gold: Vec<i32> =
            (0..m).map(|_| 80 + rng.below(8) as i32).collect();
        let f = token_f1(&pred, &gold);
        assert!((0.0..=1.0).contains(&f), "seed {seed}: f1 {f}");
        // identity
        assert_eq!(token_f1(&gold, &gold), 1.0);
        // symmetry of the overlap-based F1
        let g = token_f1(&gold, &pred);
        assert!((f - g).abs() < 1e-12, "seed {seed}: asymmetric {f} {g}");
        // permutation invariance
        let mut shuffled = pred.clone();
        rng.shuffle(&mut shuffled);
        assert_eq!(token_f1(&shuffled, &gold), f, "seed {seed}");
    }
}

#[test]
fn prop_cosine_bounds_and_scale_invariance() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xc0);
        let d = 2 + rng.below(16);
        let a: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let c = cosine(&a, &b);
        assert!((-1.0 - 1e-5..=1.0 + 1e-5).contains(&c), "seed {seed}");
        let scaled: Vec<f32> = a.iter().map(|x| x * 7.5).collect();
        let c2 = cosine(&scaled, &b);
        assert!((c - c2).abs() < 1e-4, "seed {seed}: {c} vs {c2}");
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-5, "seed {seed}");
    }
}

#[test]
fn prop_powerlaw_fit_recovers_planted_exponent() {
    for seed in 0..100 {
        let mut rng = Rng::new(seed ^ 0x99);
        let alpha = 0.2 + 2.3 * rng.next_f32();
        let c = 0.5 + rng.next_f32();
        let n = 16 + rng.below(48);
        let ys: Vec<f32> =
            (1..=n).map(|x| c * (x as f32).powf(-alpha)).collect();
        let (got, _) = powerlaw_fit(&ys);
        assert!((got - alpha).abs() < 1e-2,
                "seed {seed}: planted {alpha}, got {got}");
    }
}

#[test]
fn prop_tensor_slice_at_equals_manual_offset() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7e);
        let dims: Vec<usize> = (0..3).map(|_| 1 + rng.below(5)).collect();
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let t = Tensor::new(dims.clone(), data).unwrap();
        let i = rng.below(dims[0]);
        let j = rng.below(dims[1]);
        let s = t.slice_at(&[i, j]);
        for (k, &v) in s.iter().enumerate() {
            assert_eq!(v, t.at(&[i, j, k]), "seed {seed}");
        }
    }
}

#[test]
fn prop_rng_shuffle_is_permutation() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5f);
        let n = 1 + rng.below(64);
        let mut xs: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "seed {seed}");
    }
}

#[test]
fn prop_batcher_never_exceeds_max() {
    use samkv::coordinator::batcher::next_batch;
    use std::sync::mpsc;
    use std::time::Duration;
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0xba);
        let (tx, rx) = mpsc::channel();
        let total = 1 + rng.below(30);
        for i in 0..total {
            tx.send(i).unwrap();
        }
        drop(tx);
        let max = 1 + rng.below(8);
        let mut seen = Vec::new();
        while let Some(batch) =
            next_batch(&rx, max, Duration::from_millis(1))
        {
            assert!(batch.len() <= max, "seed {seed}");
            seen.extend(batch);
        }
        assert_eq!(seen, (0..total).collect::<Vec<_>>(), "seed {seed}");
    }
}

#[test]
fn prop_cross_filter_output_is_subset_of_picks() {
    use samkv::config::ProfileConfig;
    use samkv::sparse::{cross_filter, DocSelection};
    let cfg_json = r#"{"name":"t","n_layers":2,"d_model":8,"n_heads":1,
        "head_dim":4,"d_ff":8,"vocab":16,"n_docs":4,"doc_len":32,
        "block_size":4,"init_blocks":1,"local_blocks":1,
        "sel_cap_blocks":4,"stable_layers":2,"rope_theta":10000.0,
        "query_len":5,"answer_max":4,"ctx_len":128,"full_len":137,
        "sparse_kv_len":48,"sparse_len":57,"blocks_per_doc":8,
        "comp_len":32}"#;
    let cfg =
        ProfileConfig::from_json(&json::parse(cfg_json).unwrap()).unwrap();
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xcf);
        let sels: Vec<DocSelection> = (0..4)
            .map(|_| {
                let scores: Vec<f32> =
                    (0..8).map(|_| rng.normal() as f32).collect();
                let n_pick = rng.below(6);
                let mut picked: Vec<usize> = (1..7).collect();
                rng.shuffle(&mut picked);
                picked.truncate(n_pick);
                picked.sort_unstable();
                DocSelection { p: 0.5, p_per_layer: vec![], scores, picked }
            })
            .collect();
        let out = cross_filter(&cfg, &sels);
        let total: usize = out.iter().map(|v| v.len()).sum();
        assert!(total <= cfg.sel_cap_blocks, "seed {seed}");
        for (d, blocks) in out.iter().enumerate() {
            for b in blocks {
                assert!(sels[d].picked.contains(b),
                        "seed {seed}: doc {d} block {b} not picked");
            }
        }
    }
}

#[test]
fn prop_personalized_query_is_identity_without_bias() {
    use samkv::sparse::personalized_queries;
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0xe1);
        let shape = [2usize, 2, 4];
        let n: usize = shape.iter().product();
        let q = Tensor::new(shape.to_vec(),
                            (0..n).map(|_| rng.normal() as f32).collect())
            .unwrap();
        let l1 = Tensor::new(shape.to_vec(),
                             (0..n).map(|_| rng.normal() as f32).collect())
            .unwrap();
        let l2 = Tensor::new(shape.to_vec(),
                             (0..n).map(|_| rng.normal() as f32).collect())
            .unwrap();
        let out = personalized_queries(&q, &[&l1, &l2], false);
        assert_eq!(out[0], q, "seed {seed}");
        assert_eq!(out[1], q, "seed {seed}");
        // with bias, outputs differ across docs unless locals coincide
        let out_b = personalized_queries(&q, &[&l1, &l2], true);
        assert_ne!(out_b[0], out_b[1], "seed {seed}");
    }
}
