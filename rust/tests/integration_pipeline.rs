//! Full-pipeline integration over the tiny artifacts: every policy
//! serves real samples end-to-end through PJRT, and the paper's
//! structural invariants hold (sequence/recompute ratios, memory
//! ordering, ablation switch behaviour). Quality (F1) is NOT asserted
//! here — tiny is untrained; quality shape is asserted by the benches
//! on the trained profiles.
//!
//! Tests no-op when artifacts aren't built.

use samkv::config::{SamKvConfig, UpdateStrategy};
use samkv::eval::{evaluate, token_f1};
use samkv::kvcache::EngineDocCache;
use samkv::model::Model;
use samkv::policies::{all_policies, CacheBlendPolicy, ContextPolicy, ReusePolicy, SamKvPolicy};
use samkv::runtime::{artifacts_dir, Runtime};
use samkv::workload::Dataset;
use std::rc::Rc;

fn setup() -> Option<(Model, Dataset)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = Rc::new(Runtime::new(dir.clone()).unwrap());
    let model = Model::load(rt, "tiny").unwrap();
    let ds =
        Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap();
    Some((model, ds))
}

#[test]
fn all_policies_produce_answers() {
    let Some((model, ds)) = setup() else { return };
    let mut store = EngineDocCache::unbounded();
    for p in all_policies() {
        let out = p.run(&model, &mut store, &ds.samples[0]).unwrap();
        assert!(out.answer.len() <= model.cfg.answer_max,
                "{} answer too long", p.name());
        assert!(out.stats.ttft_ms > 0.0, "{} no ttft", p.name());
        // answers contain no specials below EOS
        for &t in &out.answer {
            assert!(t >= samkv::tokenizer::EOS, "{} bad token {t}",
                    p.name());
        }
    }
}

#[test]
fn sequence_ratios_match_paper_structure() {
    let Some((model, ds)) = setup() else { return };
    let n = 4.min(ds.samples.len());
    let full_kv: Vec<&str> = vec!["Reuse", "CacheBlend", "EPIC"];
    for p in all_policies() {
        let r = evaluate(&model, p.as_ref(), &ds, n).unwrap();
        let name = p.name();
        if full_kv.contains(&name.as_str()) || name == "Recompute" {
            assert!((r.mean_seq_ratio - 1.0).abs() < 1e-9,
                    "{name} seq ratio {}", r.mean_seq_ratio);
        } else {
            // sparse methods: strictly below full, above the fixed floor
            assert!(r.mean_seq_ratio < 1.0, "{name} not sparse");
            let floor = (model.cfg.fixed_blocks_per_doc()
                * model.cfg.block_size * model.cfg.n_docs) as f64
                / model.cfg.ctx_len as f64;
            assert!(r.mean_seq_ratio >= floor - 1e-9,
                    "{name} below floor: {}", r.mean_seq_ratio);
        }
        match name.as_str() {
            "Recompute" => {
                assert!((r.mean_recompute_ratio - 1.0).abs() < 1e-9)
            }
            "Reuse" | "Multi-InfLLM" => {
                assert_eq!(r.mean_recompute_ratio, 0.0)
            }
            _ => {
                assert!(r.mean_recompute_ratio > 0.0
                        && r.mean_recompute_ratio < 0.8,
                        "{name} recompute ratio {}",
                        r.mean_recompute_ratio);
            }
        }
    }
}

#[test]
fn samkv_memory_strictly_below_full_load() {
    let Some((model, ds)) = setup() else { return };
    let n = 4.min(ds.samples.len());
    let samkv =
        evaluate(&model,
                 &SamKvPolicy::new(SamKvConfig::default()), &ds, n)
            .unwrap();
    let blend =
        evaluate(&model, &CacheBlendPolicy::default(), &ds, n).unwrap();
    assert!(samkv.mean_kv_bytes < blend.mean_kv_bytes * 0.8,
            "samkv {} vs blend {}", samkv.mean_kv_bytes,
            blend.mean_kv_bytes);
}

#[test]
fn ablation_switches_change_behaviour() {
    let Some((model, ds)) = setup() else { return };
    let mut store = EngineDocCache::unbounded();
    let s = &ds.samples[0];
    let no_sel = SamKvPolicy::new(SamKvConfig {
        selection: false,
        recompute: false,
        ..SamKvConfig::default()
    });
    let sel = SamKvPolicy::new(SamKvConfig {
        selection: true,
        recompute: false,
        ..SamKvConfig::default()
    });
    let r0 = no_sel.run(&model, &mut store, s).unwrap();
    let r1 = sel.run(&model, &mut store, s).unwrap();
    // selection may add blocks, never remove the fixed floor
    assert!(r1.stats.seq_ratio >= r0.stats.seq_ratio - 1e-12);
    assert_eq!(r0.stats.recompute_ratio, 0.0);
    let rec = SamKvPolicy::new(SamKvConfig::default());
    let r2 = rec.run(&model, &mut store, s).unwrap();
    assert!(r2.stats.recompute_ratio > 0.0);
}

#[test]
fn overwrite_and_fusion_may_differ_but_both_serve() {
    let Some((model, ds)) = setup() else { return };
    let mut store = EngineDocCache::unbounded();
    let s = &ds.samples[1 % ds.samples.len()];
    let over = SamKvPolicy::new(SamKvConfig {
        update: UpdateStrategy::Overwrite,
        ..SamKvConfig::default()
    });
    let fuse = SamKvPolicy::new(SamKvConfig::default());
    let a = over.run(&model, &mut store, s).unwrap();
    let b = fuse.run(&model, &mut store, s).unwrap();
    assert_eq!(a.stats.seq_ratio, b.stats.seq_ratio);
    assert_eq!(a.stats.recompute_ratio, b.stats.recompute_ratio);
}

#[test]
fn offloaded_scoring_matches_host_scoring_selection() {
    let Some((model, ds)) = setup() else { return };
    let mut store = EngineDocCache::unbounded();
    let s = &ds.samples[0];
    let host = SamKvPolicy::new(SamKvConfig {
        offload_scoring: false,
        recompute: false,
        ..SamKvConfig::default()
    });
    let off = SamKvPolicy::new(SamKvConfig {
        offload_scoring: true,
        recompute: false,
        ..SamKvConfig::default()
    });
    let a = host.run(&model, &mut store, s).unwrap();
    let b = off.run(&model, &mut store, s).unwrap();
    // same selection -> same sparse geometry and same answer
    assert_eq!(a.stats.seq_ratio, b.stats.seq_ratio);
    assert_eq!(a.answer, b.answer);
}

#[test]
fn doc_cache_hits_across_requests() {
    let Some((model, ds)) = setup() else { return };
    let mut store = EngineDocCache::unbounded();
    let p = SamKvPolicy::new(SamKvConfig::default());
    let s = &ds.samples[0];
    let first = p.run(&model, &mut store, s).unwrap();
    assert!(!first.stats.cache_warm);
    let second = p.run(&model, &mut store, s).unwrap();
    assert!(second.stats.cache_warm);
    assert_eq!(first.answer, second.answer,
               "caching must not change results");
    assert!(store.stats().hits >= model.cfg.n_docs as u64);
}

#[test]
fn evaluate_aggregates_consistently() {
    let Some((model, ds)) = setup() else { return };
    let r = evaluate(&model, &ReusePolicy, &ds, 3).unwrap();
    assert_eq!(r.n, 3);
    assert!(r.f1 >= 0.0 && r.f1 <= 100.0);
    assert!(r.em >= 0.0 && r.em <= 1.0);
    let total: usize = r.per_type.iter().map(|(_, _, c)| c).sum();
    assert_eq!(total, 3);
    // token_f1 sanity on a known pair
    assert_eq!(token_f1(&[80], &[80]), 1.0);
}
