//! Persistent disk-tier integration over the tiny artifacts: a served
//! request's document KV caches must survive a full process-side cache
//! stack teardown (engine + host tier + disk handle all dropped) and
//! be served after the "restart" with **zero** model prefills and
//! token-identical output; a corrupt cache file must be quarantined
//! and fall back to a prefill without failing the request.
//!
//! Tests no-op when artifacts aren't built.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use samkv::config::{DiskWriteback, ServingConfig};
use samkv::coordinator::{Engine, Router, ServeRequest, ServeResponse};
use samkv::kvcache::{doc_hash, DiskDocCache, HostDocCache};
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::workload::{Dataset, Sample};

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

fn cache_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("samkv-itest-disk-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One complete process-side serving stack over a disk cache dir:
/// fresh metrics, fresh host tier (write-through to disk), one engine.
/// Serves the sample and returns (response, metrics, disk handle).
/// Dropping everything it allocated is the "process restart".
fn serve_once(dir: &PathBuf, sample: &Sample)
              -> (ServeResponse, Arc<Metrics>, Arc<DiskDocCache>) {
    let metrics = Arc::new(Metrics::new());
    let disk = Arc::new(DiskDocCache::open(dir, usize::MAX).unwrap());
    let host = Arc::new(
        HostDocCache::unbounded()
            .with_disk(Arc::clone(&disk), DiskWriteback::Through),
    );
    let router = Arc::new(Router::new(1));
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "Reuse".to_string(), Arc::clone(&metrics),
                               host, Some(router.residency_handle(0)))
        .unwrap();
    let resp = engine
        .handle()
        .serve(ServeRequest {
            id: 1,
            sample: sample.clone(),
            policy: String::new(),
            stream: false,
        })
        .unwrap();
    (resp, metrics, disk)
}

#[test]
fn warm_restart_serves_with_zero_prefills() {
    let Some(ds) = ready() else { return };
    let dir = cache_dir("warm");
    let sample = ds.samples[0].clone();
    let n_unique = sample
        .docs
        .iter()
        .map(|d| doc_hash(d))
        .collect::<std::collections::HashSet<_>>()
        .len() as u64;

    // --- cold process: prefills, write-through spills to disk --------
    let cold_answer;
    {
        let (resp, metrics, disk) = serve_once(&dir, &sample);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.stats.cache_warm, "first run must be cold");
        assert!(metrics.doc_prefills.load(Ordering::Relaxed) > 0,
                "cold run must prefill");
        assert_eq!(disk.stats().spills, n_unique,
                   "write-through must persist each unique doc once");
        cold_answer = resp.answer;
        // everything process-side drops here: engine threads join, the
        // host tier and the disk index are gone — only files remain
    }

    // --- "restarted" process: same dir, fresh stack ------------------
    {
        let (resp, metrics, disk) = serve_once(&dir, &sample);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.answer, cold_answer,
                   "warm restart must be token-identical");
        assert!(resp.stats.cache_warm,
                "disk-served docs must count as a warm cache");
        assert_eq!(metrics.doc_prefills.load(Ordering::Relaxed), 0,
                   "a previously-seen document must never re-prefill \
                    after a restart");
        assert!(disk.stats().hits >= n_unique,
                "every unique doc must load from disk");
        assert_eq!(disk.stats().corrupt, 0);
        assert!(metrics.disk_hits.load(Ordering::Relaxed) >= n_unique,
                "disk hits must flush into the metrics registry");
        assert!(metrics.report().contains("disk(hits="));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_cache_file_quarantined_and_request_succeeds() {
    let Some(ds) = ready() else { return };
    let dir = cache_dir("corrupt");
    let sample = ds.samples[0].clone();

    let cold_answer = {
        let (resp, _, _) = serve_once(&dir, &sample);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        resp.answer
    };

    // truncate one cache file mid-payload: the header stays valid (the
    // restart scan indexes it) but the checksum read must fail at load
    // time, exercising the per-request quarantine + prefill fallback
    let victim = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| {
            p.extension().map(|x| x == "kv").unwrap_or(false)
        })
        .expect("a spilled cache file");
    let bytes = std::fs::read(&victim).unwrap();
    assert!(bytes.len() > 64);
    std::fs::write(&victim, &bytes[..64]).unwrap();

    {
        let (resp, metrics, disk) = serve_once(&dir, &sample);
        assert!(resp.error.is_none(),
                "corrupt cache file must not fail the request: {:?}",
                resp.error);
        assert_eq!(resp.answer, cold_answer,
                   "fallback prefill must be token-identical");
        assert_eq!(disk.stats().corrupt, 1,
                   "the truncated file must be detected");
        assert!(metrics.doc_prefills.load(Ordering::Relaxed) > 0,
                "the corrupt doc must fall back to a model prefill");
        assert!(!victim.exists(),
                "corrupt file must leave its content address");
        assert!(dir.join("quarantine").exists(),
                "corrupt file must be quarantined, not deleted");
        // write-through re-persisted the re-prefilled document
        assert!(disk.stats().spills >= 1);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
