//! Lane-padded batched decode parity over the tiny artifacts: a fused
//! round over N same-buffer sessions must be ONE runtime execution
//! (checked both through `DecodeRound`'s accounting and the runtime's
//! own per-entry stats) and must produce token streams identical to the
//! per-request scalar path — across mixed `Sparse`/`Full` buffers,
//! ragged completion (sessions finishing mid-round), and a mid-round
//! per-lane failure that must not poison its sibling lanes.
//!
//! Tests no-op when artifacts aren't built; the execution-count asserts
//! additionally no-op when the artifact set predates the batched
//! entries (`decode_{sparse,full}_batched`).

use samkv::kvcache::EngineDocCache;
use samkv::model::{Buffer, DecodeReq, DecodeRound, Model};
use samkv::policies::{
    policy_by_name, ContextPolicy, NullSink, ServeSession,
};
use samkv::runtime::{artifacts_dir, Runtime};
use samkv::tensor::Tensor;
use samkv::workload::{assemble_full, Dataset, Sample};

fn setup() -> Option<(Model, Dataset)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists()
        || !dir.join("tiny_weights.bin").exists()
    {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = std::rc::Rc::new(Runtime::new(dir.clone()).unwrap());
    let model = Model::load(rt, "tiny").unwrap();
    let ds = Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json"))
        .unwrap();
    Some((model, ds))
}

/// Plan → prefill → assemble → attend one session.
fn attended<'a>(policy: &'a dyn ContextPolicy, model: &Model,
                store: &mut EngineDocCache, sample: &Sample)
                -> ServeSession<'a, dyn ContextPolicy> {
    let mut s = ServeSession::new(policy, &model.cfg, sample.clone());
    s.prefill_docs(model, store).unwrap();
    s.assemble(model).unwrap();
    s.attend(model).unwrap();
    s
}

struct RoundInfo {
    executions: u64,
    lanes_live: u64,
    lanes_total: u64,
    dispatched: usize,
    sparse: usize,
    full: usize,
}

/// Drive one fused round the way the engine does (emit half, one
/// `decode_batch` call, completion half). `None` when no session
/// wanted logits.
fn drive_round(model: &Model,
               sessions: &mut [ServeSession<'_, dyn ContextPolicy>])
               -> Option<RoundInfo> {
    let mut pending = Vec::new();
    for (i, s) in sessions.iter_mut().enumerate() {
        let mut sink = NullSink;
        let (_, step) = s.decode_step_begin(&mut sink).unwrap();
        if let Some(st) = step {
            pending.push((i, st));
        }
    }
    if pending.is_empty() {
        return None;
    }
    let reqs: Vec<DecodeReq> = pending
        .iter()
        .map(|&(i, st)| {
            let (buffer, kv, kv_valid) =
                sessions[i].decode_inputs().unwrap();
            DecodeReq { buffer, token: st.token, pos: st.pos,
                        slot: st.slot as i32, kv, kv_valid }
        })
        .collect();
    let sparse =
        reqs.iter().filter(|r| r.buffer == Buffer::Sparse).count();
    let full = reqs.len() - sparse;
    let DecodeRound { results, executions, lanes_live, lanes_total } =
        model.decode_batch(&reqs);
    drop(reqs);
    let dispatched = pending.len();
    for (&(i, st), out) in pending.iter().zip(results) {
        sessions[i]
            .decode_step_complete(st, out.unwrap(), 0.0)
            .unwrap();
    }
    Some(RoundInfo { executions, lanes_live, lanes_total, dispatched,
                     sparse, full })
}

/// Executions a round must cost: one per lane chunk for batched
/// same-buffer groups of 2+, one per request otherwise.
fn expected_execs(model: &Model, sparse: usize, full: usize) -> u64 {
    let group = |buffer: Buffer, k: usize| -> u64 {
        if k == 0 {
            return 0;
        }
        match model.batched_decode_lanes(buffer) {
            Some(lanes) if k >= 2 => ((k + lanes - 1) / lanes) as u64,
            _ => k as u64,
        }
    };
    group(Buffer::Sparse, sparse) + group(Buffer::Full, full)
}

/// Three same-buffer sessions with staggered starts: every fused round
/// over 2+ of them is exactly one execution, sessions finish raggedly
/// mid-round without disturbing the others, and every final answer is
/// token-identical to the blocking `run()` path.
#[test]
fn batched_rounds_single_execution_and_token_identical() {
    let Some((model, ds)) = setup() else { return };
    let policy = policy_by_name("Reuse").unwrap();
    let n = 3usize;
    let samples: Vec<Sample> = (0..n)
        .map(|i| ds.samples[i % ds.samples.len()].clone())
        .collect();
    let expects: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            policy
                .run(&model, &mut EngineDocCache::unbounded(), s)
                .unwrap()
                .answer
        })
        .collect();

    let batched = model.batched_decode_lanes(Buffer::Full).is_some();
    let lanes = model.cfg.decode_lanes;
    let mut store = EngineDocCache::unbounded();
    let mut sessions: Vec<ServeSession<'_, dyn ContextPolicy>> = vec![
        attended(policy.as_ref(), &model, &mut store, &samples[0]),
        attended(policy.as_ref(), &model, &mut store, &samples[1]),
    ];
    // ragged start: two sessions decode one round before the third joins
    drive_round(&model, &mut sessions);
    sessions.push(attended(policy.as_ref(), &model, &mut store,
                           &samples[2]));

    for _ in 0..2 * model.cfg.answer_max + 4 {
        let Some(info) = drive_round(&model, &mut sessions) else {
            break;
        };
        assert_eq!(info.sparse, 0);
        assert_eq!(info.executions,
                   expected_execs(&model, 0, info.full));
        if batched && info.dispatched >= 2 && info.dispatched <= lanes {
            // the tentpole claim: N same-buffer sessions, ONE execution
            assert_eq!(info.executions, 1,
                       "{} sessions took {} executions",
                       info.dispatched, info.executions);
            assert_eq!(info.lanes_live, info.dispatched as u64);
            assert_eq!(info.lanes_total, lanes as u64);
        }
    }
    assert!(sessions.iter().all(|s| s.is_done()),
            "sessions did not finish within the round bound");
    for (i, (s, want)) in sessions.iter().zip(&expects).enumerate() {
        assert_eq!(s.answer(), want.as_slice(),
                   "batched decode diverged from run() on session {i}");
    }
}

/// The one-execution claim cross-checked against the runtime's own
/// per-entry stats: a 3-session round bumps `decode_full_batched` by
/// exactly one call and never touches the scalar entry.
#[test]
fn runtime_stats_show_one_batched_call_per_round() {
    let Some((model, ds)) = setup() else { return };
    if model.batched_decode_lanes(Buffer::Full).is_none() {
        eprintln!("skipping: artifact set predates batched entries");
        return;
    }
    let policy = policy_by_name("Reuse").unwrap();
    let n = 3.min(model.cfg.decode_lanes); // one lane chunk exactly
    let mut store = EngineDocCache::unbounded();
    let mut sessions: Vec<ServeSession<'_, dyn ContextPolicy>> = (0..n)
        .map(|i| {
            attended(policy.as_ref(), &model, &mut store,
                     &ds.samples[i % ds.samples.len()])
        })
        .collect();
    let rt = model.runtime().clone();
    rt.reset_stats();
    let info = drive_round(&model, &mut sessions).expect("a round ran");
    assert_eq!(info.dispatched, n);
    let stats = rt.stats();
    let calls = |entry: &str| {
        stats
            .iter()
            .find(|(n, _)| *n == format!("tiny:{entry}"))
            .map(|(_, s)| s.calls)
            .unwrap_or(0)
    };
    assert_eq!(calls("decode_full_batched"), 1,
               "the round must be exactly one batched execution");
    assert_eq!(calls("decode_full"), 0,
               "no scalar decode may run inside a batched round");
}

/// Mixed `Sparse`/`Full` rounds: one execution per buffer-kind group,
/// and every session still token-identical to its blocking path.
#[test]
fn mixed_buffers_one_execution_per_group() {
    let Some((model, ds)) = setup() else { return };
    let reuse = policy_by_name("Reuse").unwrap(); // Full buffer
    let samkv = policy_by_name("SamKV-fusion").unwrap(); // Sparse buffer
    let s0 = ds.samples[0].clone();
    let s1 = ds.samples[1 % ds.samples.len()].clone();
    let mut expects: Vec<Vec<i32>> = Vec::new();
    for (p, s) in [(&reuse, &s0), (&reuse, &s1), (&samkv, &s0),
                   (&samkv, &s1)] {
        expects.push(
            p.run(&model, &mut EngineDocCache::unbounded(), s)
                .unwrap()
                .answer,
        );
    }
    let mut store = EngineDocCache::unbounded();
    let mut sessions: Vec<ServeSession<'_, dyn ContextPolicy>> = vec![
        attended(reuse.as_ref(), &model, &mut store, &s0),
        attended(reuse.as_ref(), &model, &mut store, &s1),
        attended(samkv.as_ref(), &model, &mut store, &s0),
        attended(samkv.as_ref(), &model, &mut store, &s1),
    ];
    let both_batched = model
        .batched_decode_lanes(Buffer::Full)
        .and(model.batched_decode_lanes(Buffer::Sparse))
        .is_some();
    for _ in 0..2 * model.cfg.answer_max + 4 {
        let Some(info) = drive_round(&model, &mut sessions) else {
            break;
        };
        assert_eq!(info.executions,
                   expected_execs(&model, info.sparse, info.full));
        if both_batched && info.sparse >= 2 && info.full >= 2 {
            assert_eq!(info.executions, 2,
                       "a mixed round must be one execution per \
                        buffer-kind group");
        }
    }
    assert!(sessions.iter().all(|s| s.is_done()));
    for (i, (s, want)) in sessions.iter().zip(&expects).enumerate() {
        assert_eq!(s.answer(), want.as_slice(),
                   "mixed-buffer batched decode diverged on session {i}");
    }
}

/// A poisoned lane (malformed KV / valid-mask inputs) fails alone: its
/// `Result` is an error while sibling lanes decode normally and match
/// the scalar entry token-for-token.
#[test]
fn poisoned_lane_fails_alone() {
    let Some((model, ds)) = setup() else { return };
    let cfg = model.cfg.clone();
    let sample = ds.samples[0].clone();
    let (tokens, valid, ans_start) = assemble_full(&sample, &cfg);
    let kv_full = model.prefill_full(&tokens, &valid).unwrap();
    let last = ans_start - 1;
    let kv_valid: Vec<f32> = (0..cfg.full_len)
        .map(|i| if i < last { 1.0 } else { 0.0 })
        .collect();
    let prev_valid: Vec<f32> = (0..cfg.full_len)
        .map(|i| if i + 1 < last { 1.0 } else { 0.0 })
        .collect();
    let bad_kv = Tensor::zeros(&[3]); // wrong shape: fails validation
    let reqs = [
        DecodeReq { buffer: Buffer::Full, token: tokens[last],
                    pos: last as i32, slot: last as i32, kv: &kv_full,
                    kv_valid: &kv_valid },
        DecodeReq { buffer: Buffer::Full, token: tokens[last],
                    pos: last as i32, slot: last as i32, kv: &bad_kv,
                    kv_valid: &kv_valid },
        DecodeReq { buffer: Buffer::Full, token: tokens[last - 1],
                    pos: last as i32 - 1, slot: last as i32 - 1,
                    kv: &kv_full, kv_valid: &prev_valid },
    ];
    let round = model.decode_batch(&reqs);
    assert_eq!(round.results.len(), 3);
    if model.batched_decode_lanes(Buffer::Full).is_some() {
        // the two healthy lanes still shared one batched execution
        assert_eq!(round.executions, 1);
        assert_eq!(round.lanes_live, 2);
    }
    let mut it = round.results.into_iter();
    let r0 = it.next().unwrap().expect("healthy lane 0 must decode");
    let r1 = it.next().unwrap();
    let r2 = it.next().unwrap().expect("healthy lane 2 must decode");
    let err = r1.expect_err("poisoned lane must fail");
    assert!(format!("{err:#}").contains("kv shape"), "{err:#}");
    // siblings match the scalar entry
    for (r, (tok, sl, vd)) in [
        (&r0, (tokens[last], last, &kv_valid)),
        (&r2, (tokens[last - 1], last - 1, &prev_valid)),
    ] {
        let want = model
            .decode(Buffer::Full, tok, sl as i32, sl as i32, &kv_full, vd)
            .unwrap();
        assert_eq!(Model::argmax(&r.logits), Model::argmax(&want.logits));
        let max_err = r
            .logits
            .iter()
            .zip(&want.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "batched vs scalar logits drifted \
                                 ({max_err})");
    }
    // a wrong-length valid mask is also caught per-lane
    let short = vec![1.0f32; 3];
    let reqs = [DecodeReq { buffer: Buffer::Full, token: tokens[last],
                            pos: last as i32, slot: last as i32,
                            kv: &kv_full, kv_valid: &short }];
    let round = model.decode_batch(&reqs);
    assert!(round.results[0].is_err());
    assert_eq!(round.executions, 0, "invalid inputs must fail before \
                                     any dispatch");
}
