//! Continuous-batching scheduler integration over the tiny artifacts:
//! mid-decode admission (a request submitted while another is decoding
//! streams its first token before the earlier request's `Done`),
//! round-robin token fairness of fused decode rounds under staggered
//! arrivals, token-level equivalence of the persistent scheduler with
//! the blocking `run()` path, and the serving snapshot on the server
//! metrics wire.
//!
//! Tests no-op when artifacts aren't built.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use samkv::config::ServingConfig;
use samkv::coordinator::{
    recv_done, Engine, ServeEvent, ServeRequest, ServeResponse,
};
use samkv::kvcache::{EngineDocCache, HostDocCache};
use samkv::metrics::Metrics;
use samkv::model::{DecodeReq, Model};
use samkv::policies::{
    policy_by_name, ContextPolicy, NullSink, ReusePolicy, ServeSession,
};
use samkv::runtime::{artifacts_dir, Runtime};
use samkv::server::{Client, Server};
use samkv::workload::Dataset;

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn setup_model() -> Model {
    let rt = std::rc::Rc::new(Runtime::new(artifacts_dir()).unwrap());
    Model::load(rt, "tiny").unwrap()
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

fn spawn_one(policy: &str, metrics: &Arc<Metrics>) -> Engine {
    Engine::spawn(0, artifacts_dir(), tiny_cfg(), policy.to_string(),
                  Arc::clone(metrics),
                  Arc::new(HostDocCache::unbounded()), None)
        .unwrap()
}

/// A request submitted while an earlier request is mid-decode must
/// stream its first token before the earlier request's terminal event:
/// the scheduler admits between decode rounds instead of draining the
/// running batch. The overlap also forces fused rounds covering both
/// sessions, which the metrics counters must show (one dispatch per
/// round: sessions-per-round strictly above one round apiece).
#[test]
fn mid_decode_admission_streams_before_prior_done() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("Reuse", &metrics);
    let h = engine.handle();
    let mut admitted_mid_decode = false;
    for attempt in 0..25u32 {
        // fresh document contents per attempt (cold store every time)
        let mut s1 = ds.samples[attempt as usize % ds.samples.len()].clone();
        let mut s2 =
            ds.samples[(attempt as usize + 1) % ds.samples.len()].clone();
        for d in &mut s1.docs {
            d[1] = samkv::tokenizer::filler_tok((attempt % 40) as i32);
        }
        for d in &mut s2.docs {
            d[2] =
                samkv::tokenizer::filler_tok((40 + attempt % 40) as i32);
        }
        let rx1 = h
            .submit(ServeRequest { id: 1, sample: s1,
                                   policy: String::new(), stream: true })
            .unwrap();
        // wait until request 1 is mid-decode (first token streamed)
        match rx1.recv().unwrap() {
            ServeEvent::Token { .. } => {}
            ServeEvent::Done(_) => continue, // decoded too fast; retry
        }
        let rx2 = h
            .submit(ServeRequest { id: 2, sample: s2,
                                   policy: String::new(), stream: true })
            .unwrap();
        // block for request 2's first event
        let first2 = rx2.recv().unwrap();
        let got_token2 = matches!(first2, ServeEvent::Token { .. });
        // conclusive ordering without cross-channel races: messages are
        // visible to try_recv the instant they are sent, so if request
        // 1's Done is NOT queued yet, it was sent after request 2's
        // first token
        let mut r1_resp: Option<ServeResponse> = None;
        while let Ok(ev) = rx1.try_recv() {
            if let ServeEvent::Done(r) = ev {
                r1_resp = Some(r);
            }
        }
        let r1_was_done = r1_resp.is_some();
        let r1_resp = match r1_resp {
            Some(r) => r,
            None => recv_done(&rx1).unwrap(),
        };
        let r2_resp = if got_token2 {
            recv_done(&rx2).unwrap()
        } else {
            match first2 {
                ServeEvent::Done(r) => r,
                ServeEvent::Token { .. } => unreachable!(),
            }
        };
        assert!(r1_resp.error.is_none(), "{:?}", r1_resp.error);
        assert!(r2_resp.error.is_none(), "{:?}", r2_resp.error);
        if got_token2 && !r1_was_done {
            admitted_mid_decode = true;
            // the overlap must have produced at least one fused round
            // covering both sessions
            let rounds = metrics.fused_rounds.load(Ordering::Relaxed);
            let sessions =
                metrics.fused_round_sessions.load(Ordering::Relaxed);
            assert!(rounds > 0, "no fused decode rounds dispatched");
            if sessions > rounds {
                break; // some round batched 2+ sessions in one dispatch
            }
        }
    }
    assert!(admitted_mid_decode,
            "a mid-decode submission never streamed before the earlier \
             request's Done in 25 tries");
    assert!(metrics.fused_round_sessions.load(Ordering::Relaxed)
                > metrics.fused_rounds.load(Ordering::Relaxed),
            "overlapping sessions never shared a fused dispatch");
    assert_eq!(metrics.active_sessions.load(Ordering::Relaxed), 0,
               "active-session gauge must return to zero when drained");
    // executions-per-round: with the lane-padded batched entries in the
    // artifact set, a fused round over N same-buffer sessions (N <=
    // decode_lanes; here at most 2) must issue exactly ONE runtime
    // execution — so total executions equal total rounds, and the
    // 2-session rounds observed above must have gone through the
    // batched dispatch. Capability-gate via the manifest alone (no
    // second model load).
    let manifest =
        samkv::runtime::Manifest::load(artifacts_dir()).unwrap();
    let batched = manifest
        .profile("tiny")
        .map(|p| p.entrypoints.contains_key("decode_full_batched"))
        .unwrap_or(false);
    if batched {
        let rounds = metrics.fused_rounds.load(Ordering::Relaxed);
        let execs = metrics.round_executions.load(Ordering::Relaxed);
        assert_eq!(execs, rounds,
                   "a fused round issued more than one execution \
                    ({execs} executions over {rounds} rounds)");
        assert!(metrics.batched_rounds.load(Ordering::Relaxed) > 0,
                "2-session rounds never used the batched entry");
        assert!(metrics.lane_occupancy() > 0.0
                    && metrics.lane_occupancy() <= 1.0,
                "lane occupancy out of range: {}",
                metrics.lane_occupancy());
    }
    // overlapped admission: request 2's plan/prefill/assemble/attend ran
    // on the helper thread while request 1 was decoding
    assert!(metrics.assemble_overlap_ms() > 0.0,
            "mid-decode admission never overlapped a decode round");
}

/// Drive one fused decode round over a set of attended sessions the
/// way the engine does: emit half, one `decode_batch` dispatch,
/// completion half. Returns how many sessions joined the dispatch.
fn fused_round<P: ContextPolicy + ?Sized>(
    model: &Model, sessions: &mut [ServeSession<'_, P>]) -> usize {
    let mut pending = Vec::new();
    for (i, s) in sessions.iter_mut().enumerate() {
        let mut sink = NullSink;
        let (_, step) = s.decode_step_begin(&mut sink).unwrap();
        if let Some(st) = step {
            pending.push((i, st));
        }
    }
    if pending.is_empty() {
        return 0;
    }
    let reqs: Vec<DecodeReq> = pending
        .iter()
        .map(|&(i, st)| {
            let (buffer, kv, kv_valid) =
                sessions[i].decode_inputs().unwrap();
            DecodeReq { buffer, token: st.token, pos: st.pos,
                        slot: st.slot as i32, kv, kv_valid }
        })
        .collect();
    let round = model.decode_batch(&reqs);
    drop(reqs);
    let n = pending.len();
    for (&(i, st), out) in pending.iter().zip(round.results) {
        sessions[i]
            .decode_step_complete(st, out.unwrap(), 0.0)
            .unwrap();
    }
    n
}

/// Round-robin fairness under staggered arrivals: a session that joins
/// while another is decoding advances by exactly one token per fused
/// round alongside it (no session starves, none races ahead), and both
/// finish with answers token-identical to the blocking `run()` path.
#[test]
fn fused_rounds_interleave_fairly_and_match_blocking() {
    let Some(ds) = ready() else { return };
    let model = setup_model();
    let policy = ReusePolicy;
    let s0 = ds.samples[0].clone();
    let s1 = ds.samples[1 % ds.samples.len()].clone();
    let expect0 = policy
        .run(&model, &mut EngineDocCache::unbounded(), &s0)
        .unwrap()
        .answer;
    let expect1 = policy
        .run(&model, &mut EngineDocCache::unbounded(), &s1)
        .unwrap()
        .answer;

    let mut store = EngineDocCache::unbounded();
    let mut sessions: Vec<ServeSession<'_, ReusePolicy>> = Vec::new();
    let mut a = ServeSession::new(&policy, &model.cfg, s0);
    a.prefill_docs(&model, &mut store).unwrap();
    a.assemble(&model).unwrap();
    a.attend(&model).unwrap();
    sessions.push(a);
    // session 0 decodes solo for one round before session 1 arrives
    fused_round(&model, &mut sessions);
    let head_start = sessions[0].answer().len();
    let mut b = ServeSession::new(&policy, &model.cfg, s1);
    b.prefill_docs(&model, &mut store).unwrap();
    b.assemble(&model).unwrap();
    b.attend(&model).unwrap();
    sessions.push(b);

    for _round in 0..2 * model.cfg.answer_max + 4 {
        if sessions.iter().all(|s| s.is_done()) {
            break;
        }
        let before: Vec<(usize, bool)> = sessions
            .iter()
            .map(|s| (s.answer().len(), s.is_done()))
            .collect();
        fused_round(&model, &mut sessions);
        for (s, &(len, was_done)) in sessions.iter().zip(&before) {
            let gained = s.answer().len() - len;
            assert!(gained <= 1,
                    "a session advanced {gained} tokens in one round");
            if !was_done {
                // a live session either emitted its round token or hit
                // EOS/bound and is now done — it is never skipped
                assert!(gained == 1 || s.is_done(),
                        "a live session was starved for a round");
            }
        }
    }
    assert!(sessions.iter().all(|s| s.is_done()),
            "sessions did not finish within the round bound");
    assert_eq!(sessions[0].answer(), expect0.as_slice(),
               "fused decode diverged from run() for the first session");
    assert_eq!(sessions[1].answer(), expect1.as_slice(),
               "fused decode diverged from run() for the joiner \
                (head start {head_start})");
}

/// The persistent scheduler must be answer-identical to the blocking
/// `serve_blocking`/`run()` path, and per-request queue wait must be
/// reported.
#[test]
fn continuous_engine_matches_serve_blocking() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("SamKV-fusion", &metrics);
    let h = engine.handle();
    let model = setup_model();
    let policy = policy_by_name("SamKV-fusion").unwrap();
    let mut store = EngineDocCache::unbounded();
    for (k, sample) in ds.samples.iter().take(2).enumerate() {
        let resp = h
            .serve(ServeRequest { id: k as u64, sample: sample.clone(),
                                  policy: String::new(), stream: false })
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let expected =
            policy.run(&model, &mut store, sample).unwrap().answer;
        assert_eq!(resp.answer, expected,
                   "scheduler diverged from blocking path on sample {k}");
        assert!(resp.stats.queue_wait_ms >= 0.0);
        if !resp.answer.is_empty() {
            assert!(metrics.fused_rounds.load(Ordering::Relaxed) > 0,
                    "decode must go through fused rounds");
        }
    }
    assert!(metrics.queue_wait.count() >= 2,
            "queue wait must be observed per admitted request");
    assert_eq!(metrics.active_sessions.load(Ordering::Relaxed), 0);
}

/// The server metrics wire must expose the continuous-batching
/// serving snapshot and per-request queue wait.
#[test]
fn server_metrics_expose_serving_snapshot() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("Reuse", &metrics);
    let server = Server::new(vec![engine.handle()], metrics);
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let port = port_rx.recv().unwrap();
    let mut client = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let s = &ds.samples[0];
    let resp = client.request(&s.docs, &s.query, "Reuse").unwrap();
    assert!(resp.get("error").is_none(), "{resp}");
    assert!(resp.get("queue_wait_ms").unwrap().as_f64().unwrap() >= 0.0);

    let m = client.metrics().unwrap();
    let serving = m.get("serving").expect("serving object on the wire");
    for field in [
        "active_sessions", "queue_wait_p50_ms", "queue_wait_p95_ms",
        "ttft_p50_ms", "ttft_p95_ms", "fused_rounds",
        "fused_round_sessions", "batched_rounds", "round_executions",
        "executions_per_round", "lane_occupancy", "assemble_overlap_ms",
    ] {
        assert!(serving.get(field).is_some(), "missing {field}: {m}");
    }
    assert_eq!(serving.get("active_sessions").unwrap().as_i64(), Some(0));

    client.shutdown().unwrap();
    srv.join().unwrap().unwrap();
}
