//! Multi-node peer-tier integration over the tiny artifacts: two
//! in-process nodes (each a real TCP [`samkv::server::Server`] over a
//! single-engine stack) prove the prefill guarantee is cluster-wide —
//! a document node A prefilled is served by node B over `peer_get`
//! with **zero** model prefills on B and token-identical answers —
//! and that every peer failure mode (dead peer, injected `peer_fetch`
//! fault) degrades to a local prefill, never a failed request.
//!
//! Tests no-op when artifacts aren't built.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use samkv::config::ServingConfig;
use samkv::coordinator::{Engine, Router};
use samkv::faultinject::{FaultPlan, FaultSite};
use samkv::kvcache::{doc_hash, HostDocCache};
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::server::peers::{rendezvous_owner, ClusterPeers};
use samkv::server::{Client, Server};
use samkv::workload::{Dataset, Sample};

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

/// Mutate a document's filler tokens until its content hash is
/// rendezvous-owned by `owner` in a 2-node cluster (same steering
/// idiom as the chaos tests; each try flips ownership with p≈0.5, so
/// the filler grid never realistically exhausts).
fn steer_to_owner(doc: &mut [i32], owner: usize) {
    use samkv::tokenizer::{filler_tok, N_FILLERS};
    for a in 0..N_FILLERS {
        for b in 0..N_FILLERS {
            doc[1] = filler_tok(a);
            doc[2] = filler_tok(b);
            if rendezvous_owner(doc_hash(doc), 2) == owner {
                return;
            }
        }
    }
    panic!("could not steer doc ownership");
}

/// `n` dataset samples with every document steered to node 0 — node
/// 1's only warm path is then the peer fetch, so `doc_prefills == 0`
/// on node 1 is the cluster-wide exactly-once assertion.
fn steered_samples(ds: &Dataset, n: usize) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            let mut s = ds.samples[i % ds.samples.len()].clone();
            for d in &mut s.docs {
                steer_to_owner(d, 0);
            }
            s
        })
        .collect()
}

/// One in-process cluster node behind a real TCP server, its host
/// tier attached so it answers `peer_get`.
struct Node {
    metrics: Arc<Metrics>,
    addr: String,
    srv: thread::JoinHandle<anyhow::Result<()>>,
    engines: Vec<Engine>,
}

fn spawn_node(
    mk_peers: impl FnOnce(&Arc<Metrics>) -> Option<ClusterPeers>,
) -> Node {
    let metrics = Arc::new(Metrics::new());
    let mut host = HostDocCache::unbounded();
    if let Some(p) = mk_peers(&metrics) {
        host = host.with_peers(Arc::new(p));
    }
    let host = Arc::new(host);
    let router = Arc::new(Router::new(1));
    let engines = vec![Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                                     "Reuse".to_string(),
                                     Arc::clone(&metrics),
                                     Arc::clone(&host),
                                     Some(router.residency_handle(0)))
        .unwrap()];
    let handles = engines.iter().map(|e| e.handle()).collect();
    let server =
        Server::with_router(handles, Arc::clone(&metrics), router)
            .with_host(Arc::clone(&host));
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    Node { metrics, addr, srv, engines }
}

fn stop(node: Node) {
    Client::connect(&node.addr).unwrap().shutdown().unwrap();
    node.srv.join().unwrap().unwrap();
    drop(node.engines);
}

/// Serve every sample once over one connection, behind a watchdog (a
/// request with no terminal reply is the failure mode the peer tier's
/// degradation contract exists to rule out). Panics on error replies;
/// returns the answer tokens per sample.
fn drive(addr: &str, samples: &[Sample]) -> Vec<Vec<i32>> {
    let (tx, rx) = mpsc::channel();
    let addr = addr.to_string();
    let samples = samples.to_vec();
    thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        let out: Vec<Vec<i32>> = samples
            .iter()
            .map(|s| {
                let r =
                    client.request(&s.docs, &s.query, "Reuse").unwrap();
                assert!(r.get("error").is_none(), "{r}");
                r.get("answer").unwrap().i32_vec().unwrap()
            })
            .collect();
        tx.send(out).unwrap();
    });
    rx.recv_timeout(Duration::from_secs(120))
        .expect("serving hung: no terminal reply within 120s")
}

/// The headline guarantee: node A prefills the steered corpus, node B
/// (whose rendezvous owner for every doc is A) serves the same
/// workload entirely over `peer_get` — zero model prefills on B,
/// token-identical answers, and both nodes' `cmd:metrics` wire carries
/// the `schema_version` stamp and the `peers` object.
#[test]
fn cluster_wide_exactly_once_prefill_and_token_identity() {
    let Some(ds) = ready() else { return };
    let samples = steered_samples(&ds, 3);

    let a = spawn_node(|_| None);
    let answers_a = drive(&a.addr, &samples);
    assert!(a.metrics.doc_prefills.load(Ordering::Relaxed) > 0,
            "the owner pays the cluster's only prefills");

    let a_addr = a.addr.clone();
    let b = spawn_node(move |m| {
        Some(ClusterPeers::new(
            1,
            // node 1's own slot is never dialed (self-owned hashes
            // skip the fetcher), so a placeholder is fine
            vec![a_addr, "127.0.0.1:1".to_string()],
            1000,
            Arc::clone(m),
        ))
    });
    let answers_b = drive(&b.addr, &samples);

    assert_eq!(answers_a, answers_b,
               "peer-served answers must be token-identical");
    assert_eq!(b.metrics.doc_prefills.load(Ordering::Relaxed), 0,
               "node B must run zero model prefills — that IS the \
                cluster-wide exactly-once guarantee");
    assert!(b.metrics.peer_fetch_hits.load(Ordering::Relaxed) >= 1);
    assert!(b.metrics.peer_bytes_in.load(Ordering::Relaxed) > 0);
    assert_eq!(b.metrics.peers_down.load(Ordering::Relaxed), 0);

    // the typed wire: schema stamp + peers object on both sides
    let mb = Client::connect(&b.addr).unwrap().metrics().unwrap();
    assert_eq!(
        mb.get("schema_version").unwrap().as_i64(),
        Some(samkv::server::protocol::METRICS_SCHEMA_VERSION as i64),
        "{mb}");
    let p = mb.get("peers").expect("cmd:metrics must carry `peers`");
    assert!(p.get("fetch_hits").unwrap().as_i64().unwrap() >= 1, "{mb}");
    assert!(p.get("bytes_in").unwrap().as_i64().unwrap() > 0, "{mb}");
    let ma = Client::connect(&a.addr).unwrap().metrics().unwrap();
    assert!(ma.get("peers").unwrap().get("bytes_out").unwrap()
                .as_i64().unwrap() > 0,
            "the owner must count the entry bytes it served: {ma}");

    stop(b);
    stop(a);
}

/// A dead owner must cost at most the connect timeout once, then sit
/// in down-cooldown (fail-fast misses) — every request still answers
/// via local prefill, token-identical to a single-node run.
#[test]
fn peer_down_falls_back_to_local_prefill() {
    let Some(ds) = ready() else { return };
    let samples = steered_samples(&ds, 2);

    let base = spawn_node(|_| None);
    let expect = drive(&base.addr, &samples);
    stop(base);

    let b = spawn_node(|m| {
        Some(ClusterPeers::new(
            1,
            // the "owner" is a closed loopback port: every dial is
            // refused immediately
            vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()],
            200,
            Arc::clone(m),
        )
        .with_cooldown_ms(60_000))
    });
    let got = drive(&b.addr, &samples);

    assert_eq!(got, expect,
               "degraded answers must be token-identical");
    assert!(b.metrics.doc_prefills.load(Ordering::Relaxed) > 0,
            "a down peer must degrade to local prefills");
    assert!(b.metrics.peer_fetch_misses.load(Ordering::Relaxed) >= 1);
    assert_eq!(b.metrics.peer_fetch_hits.load(Ordering::Relaxed), 0);
    assert_eq!(b.metrics.peers_down.load(Ordering::Relaxed), 1,
               "the dead owner must sit in down-cooldown");

    stop(b);
}

/// A seeded `peer_fetch` fault plan fails every other fetch as an
/// injected miss; each injected miss must heal through a local
/// prefill — 100% completion, token-identical answers, and the
/// non-injected fetches still hit the owner.
#[test]
fn peer_fetch_fault_plan_heals_transparently() {
    let Some(ds) = ready() else { return };
    let samples = steered_samples(&ds, 4);

    let a = spawn_node(|_| None);
    let expect = drive(&a.addr, &samples);

    let plan =
        Arc::new(FaultPlan::parse("seed=7;peer_fetch:every=2").unwrap());
    let a_addr = a.addr.clone();
    let plan_b = Arc::clone(&plan);
    let b = spawn_node(move |m| {
        Some(ClusterPeers::new(
            1,
            vec![a_addr, "127.0.0.1:1".to_string()],
            1000,
            Arc::clone(m),
        )
        .with_faults(Some(plan_b)))
    });
    let got = drive(&b.addr, &samples);

    assert_eq!(got, expect,
               "healed answers must be token-identical");
    assert!(plan.injected(FaultSite::PeerFetch) >= 1,
            "the plan never fired — the site is not wired");
    assert!(b.metrics.doc_prefills.load(Ordering::Relaxed) >= 1,
            "injected peer misses must heal via local prefill");
    assert!(b.metrics.peer_fetch_hits.load(Ordering::Relaxed) >= 1,
            "non-injected fetches must still hit the owner");
    assert!(b.metrics.peer_fetch_misses.load(Ordering::Relaxed) >= 1);

    stop(b);
    stop(a);
}
