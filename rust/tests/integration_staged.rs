//! Staged-protocol integration over the tiny artifacts: for every
//! policy, driving the explicit plan/prefill/assemble/attend/decode
//! stages must be token-identical to the legacy blocking `run()` entry
//! point, streamed tokens must equal the final answer, and the
//! per-stage timing split must be consistent.
//!
//! Tests no-op when artifacts aren't built.

use samkv::kvcache::EngineDocCache;
use samkv::model::Model;
use samkv::policies::{
    all_policies, CollectSink, ContextPolicy, ServeSession, Stage,
};
use samkv::runtime::{artifacts_dir, Runtime};
use samkv::workload::Dataset;
use std::rc::Rc;

fn setup() -> Option<(Model, Dataset)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = Rc::new(Runtime::new(dir.clone()).unwrap());
    let model = Model::load(rt, "tiny").unwrap();
    let ds =
        Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap();
    Some((model, ds))
}

#[test]
fn staged_is_token_identical_to_run_for_every_policy() {
    let Some((model, ds)) = setup() else { return };
    let sample = &ds.samples[0]; // fixed sample; artifacts are seeded
    for policy in all_policies() {
        // legacy path: run() (the default staged blocking driver)
        let mut store_a = EngineDocCache::unbounded();
        let legacy = policy.run(&model, &mut store_a, sample).unwrap();

        // explicit staged path with streaming
        let mut store_b = EngineDocCache::unbounded();
        let mut session =
            ServeSession::new(policy.as_ref(), &model.cfg, sample.clone());
        assert_eq!(session.stage(), Stage::Planned);
        session.prefill_docs(&model, &mut store_b).unwrap();
        session.assemble(&model).unwrap();
        session.attend(&model).unwrap();
        let mut sink = CollectSink::default();
        while session.decode_step(&model, &mut sink).unwrap().is_some() {}
        assert!(session.is_done());
        let staged = session.finish();

        assert_eq!(staged.answer, legacy.answer,
                   "{}: staged != run()", policy.name());
        assert_eq!(sink.0, staged.answer,
                   "{}: streamed tokens != final answer", policy.name());
        assert_eq!(staged.stats.cache_warm, legacy.stats.cache_warm);
        assert_eq!(staged.stats.seq_ratio, legacy.stats.seq_ratio,
                   "{}", policy.name());
        assert_eq!(staged.stats.recompute_ratio,
                   legacy.stats.recompute_ratio, "{}", policy.name());
        assert!(staged.stats.ttft_ms > 0.0, "{}", policy.name());
        assert!(staged.stats.plan_ms >= 0.0);
    }
}

/// Non-circular legacy check: `run()` is now a default method over the
/// stages, so comparing it with a session exercises one code path
/// twice. This test instead re-implements the SEED's monolithic Reuse
/// serving loop (assemble + incremental query prefill + the old
/// greedy decode with its original bound structure) directly against
/// public APIs and asserts the staged pipeline reproduces it
/// token-for-token.
#[test]
fn staged_decode_matches_seed_era_reference_loop() {
    use samkv::kvcache::{AssembledContext, EngineDocCache as Store};
    use samkv::model::Buffer;
    use samkv::tokenizer as tok;

    let Some((model, ds)) = setup() else { return };
    let cfg = model.cfg.clone();
    let sample = &ds.samples[0];

    // --- reference: the pre-refactor Reuse pipeline, inlined ----------
    let mut store = Store::unbounded();
    let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
    for (d, doc) in sample.docs.iter().enumerate() {
        let (e, _) = store.get_or_prefill(&model, doc).unwrap();
        ctx.append_doc(&cfg, &e, d).unwrap();
    }
    let step = |ctx: &mut AssembledContext, t: i32, pos: i32| {
        let slot = ctx.push_token(t, pos).unwrap();
        let out = model
            .decode(Buffer::Full, t, pos, slot as i32, &ctx.kv,
                    &ctx.valid)
            .unwrap();
        ctx.write_token_kv(slot, &out.k_new, &out.v_new);
        out.logits
    };
    let q0 = cfg.ctx_len as i32;
    let mut logits: Option<Vec<f32>> = None;
    for (i, &t) in sample.query.iter().enumerate() {
        logits = Some(step(&mut ctx, t, q0 + i as i32));
    }
    // the seed's greedy loop, duplicated bound checks and all
    let mut reference = Vec::new();
    let mut pos = q0 + cfg.query_len as i32;
    let mut cur = samkv::model::Model::argmax(&logits.unwrap());
    for _ in 0..cfg.answer_max {
        if cur == tok::EOS {
            break;
        }
        reference.push(cur);
        if reference.len() >= cfg.answer_max {
            break;
        }
        let out = step(&mut ctx, cur, pos);
        cur = samkv::model::Model::argmax(&out);
        pos += 1;
    }

    // --- staged pipeline on a fresh store ------------------------------
    let staged = samkv::policies::ReusePolicy
        .run(&model, &mut EngineDocCache::unbounded(), sample)
        .unwrap();
    assert_eq!(staged.answer, reference,
               "staged Reuse diverged from the seed-era serving loop");
}

#[test]
fn plans_are_pure_and_describe_requests() {
    let Some((model, ds)) = setup() else { return };
    let sample = &ds.samples[0];
    for policy in all_policies() {
        let p1 = policy.plan(&model.cfg, sample);
        let p2 = policy.plan(&model.cfg, sample);
        assert_eq!(p1.doc_hashes, p2.doc_hashes, "{}", policy.name());
        assert_eq!(p1.needs_doc_cache, policy.uses_doc_cache());
        if p1.needs_doc_cache {
            assert_eq!(p1.doc_hashes.len(), sample.docs.len());
        } else {
            assert!(p1.doc_hashes.is_empty());
        }
    }
}

#[test]
fn stage_order_is_enforced() {
    let Some((model, ds)) = setup() else { return };
    let sample = &ds.samples[0];
    let policies = all_policies();
    let policy = policies[1].as_ref(); // Reuse
    let mut session = ServeSession::new(policy, &model.cfg, sample.clone());
    // assemble before prefill_docs must fail, not misbehave
    assert!(session.assemble(&model).is_err());
    assert!(session.attend(&model).is_err());
    let mut store = EngineDocCache::unbounded();
    session.prefill_docs(&model, &mut store).unwrap();
    assert!(session.prefill_docs(&model, &mut store).is_err());
    session.assemble(&model).unwrap();
    session.attend(&model).unwrap();
    assert!(session.attend(&model).is_err());
}

#[test]
fn warm_second_session_matches_cold_first() {
    let Some((model, ds)) = setup() else { return };
    let sample = &ds.samples[0];
    let policies = all_policies();
    let policy = policies.last().unwrap(); // SamKV-fusion
    let mut store = EngineDocCache::unbounded();
    let cold = policy.run(&model, &mut store, sample).unwrap();
    assert!(!cold.stats.cache_warm);
    let warm = policy.run(&model, &mut store, sample).unwrap();
    assert!(warm.stats.cache_warm);
    assert_eq!(cold.answer, warm.answer);
    // warm path did no document prefill work to speak of
    assert!(warm.stats.doc_prefill_ms <= cold.stats.doc_prefill_ms);
}
