//! Paged KV block-pool integration over the tiny artifacts: every
//! serving policy must produce token-identical answers regardless of
//! the pool's block span (the block size is a storage-layout knob, not
//! a semantic one), and a warm restart over block-format (v2) disk
//! files must serve with zero model prefills — including a restart
//! that changes the block span, which exercises the gather-and-reblock
//! load path.
//!
//! Tests no-op when artifacts aren't built.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use samkv::config::{DiskWriteback, ServingConfig};
use samkv::coordinator::{Engine, Router, ServeRequest, ServeResponse};
use samkv::kvcache::{DiskDocCache, HostDocCache};
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::workload::{Dataset, Sample};

const ALL_POLICIES: [&str; 7] = [
    "Recompute",
    "Reuse",
    "Multi-InfLLM",
    "CacheBlend",
    "EPIC",
    "SamKV-overwrite",
    "SamKV-fusion",
];

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

/// One serving stack whose host tier stores KV in `block_tokens`-sized
/// pool blocks; serves `sample` once per policy name and returns the
/// responses plus the stack's metrics registry.
fn serve_policies(block_tokens: usize, dir: Option<&PathBuf>,
                  sample: &Sample, policies: &[&str])
                  -> (Vec<ServeResponse>, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let mut host = HostDocCache::unbounded().with_block_tokens(block_tokens);
    if let Some(dir) = dir {
        let disk = Arc::new(DiskDocCache::open(dir, usize::MAX).unwrap());
        host = host.with_disk(disk, DiskWriteback::Through);
    }
    let host = Arc::new(host);
    let router = Arc::new(Router::new(1));
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "Reuse".to_string(), Arc::clone(&metrics),
                               host, Some(router.residency_handle(0)))
        .unwrap();
    let responses = policies
        .iter()
        .enumerate()
        .map(|(i, p)| {
            engine
                .handle()
                .serve(ServeRequest {
                    id: i as u64 + 1,
                    sample: sample.clone(),
                    policy: p.to_string(),
                    stream: false,
                })
                .unwrap()
        })
        .collect();
    (responses, metrics)
}

/// The block span must be invisible in the output: every policy's
/// answer over a fine-grained (8-token) pool must match its answer
/// over the default-span pool token for token. Also proves the pool
/// gauges flush into the metrics registry during serving.
#[test]
fn all_policies_token_identical_across_block_spans() {
    let Some(ds) = ready() else { return };
    let sample = ds.samples[0].clone();

    let (base, metrics) = serve_policies(64, None, &sample, &ALL_POLICIES);
    for (p, r) in ALL_POLICIES.iter().zip(&base) {
        assert!(r.error.is_none(), "{p}: {:?}", r.error);
        assert!(!r.answer.is_empty(), "{p}: empty answer");
    }
    assert!(metrics.pool_slots_total.load(Ordering::Relaxed) > 0,
            "pool gauges must flush into metrics during serving");
    assert!(metrics.pool_slots_live.load(Ordering::Relaxed) > 0);
    assert!(metrics.pool_slab_bytes.load(Ordering::Relaxed) > 0);
    assert!(metrics.report().contains("pool(slots="),
            "pool counters must appear in the metrics report");

    let (fine, _) = serve_policies(8, None, &sample, &ALL_POLICIES);
    for ((p, r64), r8) in ALL_POLICIES.iter().zip(&base).zip(&fine) {
        assert!(r8.error.is_none(), "{p}: {:?}", r8.error);
        assert_eq!(r8.answer, r64.answer,
                   "{p}: answers must not depend on the pool block span");
    }
}

/// Warm restart over block-format (v2) disk files: a fresh process
/// stack over the same cache dir must serve with zero model prefills
/// and token-identical output — both when the restarted pool uses the
/// same block span (per-block restore path) and when it uses a
/// different one (whole-file gather + re-block path).
#[test]
fn warm_restart_over_block_format_disk_files() {
    let Some(ds) = ready() else { return };
    let dir = std::env::temp_dir()
        .join(format!("samkv-itest-pool-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sample = ds.samples[0].clone();
    let policy = ["SamKV-fusion"];

    // --- cold process over an 8-token-block pool ----------------------
    let cold_answer = {
        let (resp, metrics) =
            serve_policies(8, Some(&dir), &sample, &policy);
        let resp = &resp[0];
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(metrics.doc_prefills.load(Ordering::Relaxed) > 0,
                "cold run must prefill");
        assert!(metrics.disk_spills.load(Ordering::Relaxed) > 0,
                "write-through must persist the documents");
        resp.answer.clone()
    };

    // --- restart with the same block span: per-block restore ----------
    {
        let (resp, metrics) =
            serve_policies(8, Some(&dir), &sample, &policy);
        let resp = &resp[0];
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.answer, cold_answer,
                   "same-span warm restart must be token-identical");
        assert_eq!(metrics.doc_prefills.load(Ordering::Relaxed), 0,
                   "warm restart must never re-prefill");
        assert!(metrics.disk_hits.load(Ordering::Relaxed) > 0);
        assert_eq!(metrics.disk_corrupt.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.disk_corrupt_blocks.load(Ordering::Relaxed), 0);
    }

    // --- restart with a different span: gather + re-block -------------
    {
        let (resp, metrics) =
            serve_policies(16, Some(&dir), &sample, &policy);
        let resp = &resp[0];
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.answer, cold_answer,
                   "cross-span warm restart must be token-identical");
        assert_eq!(metrics.doc_prefills.load(Ordering::Relaxed), 0,
                   "a block-span change must not force re-prefills");
        assert!(metrics.disk_hits.load(Ordering::Relaxed) > 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
