//! Tiered document-cache integration over the tiny artifacts: two
//! engines sharing one host tier must prefill each unique document
//! exactly once process-wide (engine B hits what engine A published),
//! visible end-to-end through the per-tier `Metrics` counters, and the
//! cache-aware router must follow residency.
//!
//! Tests no-op when artifacts aren't built.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use samkv::config::ServingConfig;
use samkv::coordinator::{Engine, Router, ServeRequest};
use samkv::kvcache::{doc_hash, HostDocCache};
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::workload::Dataset;

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

fn spawn_pair(metrics: &Arc<Metrics>, host: &Arc<HostDocCache>,
              router: &Arc<Router>) -> Vec<Engine> {
    (0..2)
        .map(|i| {
            Engine::spawn(i, artifacts_dir(), tiny_cfg(),
                          "Reuse".to_string(), Arc::clone(metrics),
                          Arc::clone(host),
                          Some(router.residency_handle(i)))
                .unwrap()
        })
        .collect()
}

#[test]
fn unique_docs_prefill_once_across_engines() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let host = Arc::new(HostDocCache::unbounded());
    let router = Arc::new(Router::new(2));
    let engines = spawn_pair(&metrics, &host, &router);
    let sample = ds.samples[0].clone();
    let n_docs: std::collections::HashSet<u64> =
        sample.docs.iter().map(|d| doc_hash(d)).collect();
    let n_docs = n_docs.len() as u64;

    // sequential: engine 0 prefills, engine 1 must hit the host tier
    let req = |id: u64| ServeRequest {
        id,
        sample: sample.clone(),
        policy: String::new(),
        stream: false,
    };
    let r0 = engines[0].handle().serve(req(0)).unwrap();
    assert!(r0.error.is_none(), "{:?}", r0.error);
    assert!(!r0.stats.cache_warm, "first request must be cold");
    let after_first = host.stats();
    assert_eq!(after_first.publishes, n_docs,
               "engine 0 must publish each unique doc once");

    let r1 = engines[1].handle().serve(req(1)).unwrap();
    assert!(r1.error.is_none(), "{:?}", r1.error);
    assert_eq!(r0.answer, r1.answer,
               "host-tier sharing must not change results");
    assert!(r1.stats.cache_warm,
            "engine 1 must be warm off engine 0's published prefills");
    let after_second = host.stats();
    assert_eq!(after_second.publishes, n_docs,
               "engine 1 must not prefill what engine 0 published");
    assert!(after_second.hits >= n_docs,
            "engine 1's lookups must be host-tier hits");

    // concurrent: fresh docs to both engines at once — the prefill
    // lease must still keep it to one publish per unique doc
    let mut s2 = ds.samples[0].clone();
    for d in &mut s2.docs {
        d[1] = samkv::tokenizer::filler_tok(3);
    }
    let uniq2: std::collections::HashSet<u64> =
        s2.docs.iter().map(|d| doc_hash(d)).collect();
    assert!(uniq2.iter().all(|h| !host.contains(*h)),
            "mutated docs must be new to the host tier");
    let rx_a = engines[0]
        .handle()
        .submit(ServeRequest { id: 10, sample: s2.clone(),
                               policy: String::new(), stream: false })
        .unwrap();
    let rx_b = engines[1]
        .handle()
        .submit(ServeRequest { id: 11, sample: s2,
                               policy: String::new(), stream: false })
        .unwrap();
    let ra = samkv::coordinator::recv_done(&rx_a).unwrap();
    let rb = samkv::coordinator::recv_done(&rx_b).unwrap();
    assert!(ra.error.is_none() && rb.error.is_none());
    assert_eq!(ra.answer, rb.answer);
    assert_eq!(host.stats().publishes, n_docs + uniq2.len() as u64,
               "concurrent engines must not double-prefill a document");

    // end-to-end visibility: the engines flushed the tier counters
    // into the shared metrics registry after serving
    assert_eq!(metrics.host_publishes.load(Ordering::Relaxed),
               host.stats().publishes);
    assert!(metrics.resident_hits.load(Ordering::Relaxed) > 0,
            "session prefill stage must hit the residency tier");
    assert!(metrics.report().contains("host(hits="));
}

#[test]
fn router_places_repeat_docsets_on_the_resident_engine() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let host = Arc::new(HostDocCache::unbounded());
    let router = Arc::new(Router::new(2));
    let engines = spawn_pair(&metrics, &host, &router);
    let sample = ds.samples[0].clone();

    // first placement (affinity or residency-free), served to warm
    // exactly one engine's residency tier
    let first = router.pick(&sample);
    let r = engines[first]
        .handle()
        .serve(ServeRequest { id: 1, sample: sample.clone(),
                              policy: String::new(), stream: false })
        .unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    router.done(first);
    for d in &sample.docs {
        assert!(router.board().is_resident(first, doc_hash(d)),
                "served engine must advertise residency");
    }

    // every repeat of the doc-set must land on the warmed engine
    for _ in 0..4 {
        let again = router.pick(&sample);
        assert_eq!(again, first,
                   "cache-aware routing must follow residency");
        router.done(again);
    }
}
