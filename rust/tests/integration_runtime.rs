//! End-to-end runtime integration: load tiny artifacts through PJRT and
//! verify the same cross-entrypoint invariants the python suite checks —
//! now through the HLO-text -> compile -> execute path the serving stack
//! uses.
//!
//! Tests no-op (pass trivially) when `artifacts/` has not been built.

use std::rc::Rc;

use samkv::model::{Buffer, Model};
use samkv::runtime::{artifacts_dir, Runtime};
use samkv::tensor::Tensor;
use samkv::workload::{assemble_full, Dataset};

fn setup() -> Option<(Rc<Runtime>, Model, samkv::workload::Sample)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists()
        || !dir.join("tiny_weights.bin").exists()
    {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = Rc::new(Runtime::new(dir.clone()).expect("runtime"));
    let model = Model::load(rt.clone(), "tiny").expect("tiny model");
    let ds = Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json"))
        .expect("tiny dataset");
    let sample = ds.samples[0].clone();
    Some((rt, model, sample))
}

#[test]
fn prefill_doc_shapes_and_probs() {
    let Some((_rt, model, sample)) = setup() else { return };
    let cfg = &model.cfg;
    let out = model.prefill_doc(&sample.docs[0], 0).unwrap();
    assert_eq!(out.kv.shape(), &[cfg.n_layers, 2, cfg.n_heads, cfg.doc_len,
                                 cfg.head_dim]);
    assert_eq!(out.attn.shape(), &[cfg.n_layers, cfg.n_heads, cfg.doc_len,
                                   cfg.doc_len]);
    assert_eq!(out.q_local.shape(), &[cfg.n_layers, cfg.n_heads,
                                      cfg.head_dim]);
    // each attention row sums to 1
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            for q in 0..cfg.doc_len {
                let row: f32 =
                    out.attn.slice_at(&[l, h, q]).iter().sum();
                assert!((row - 1.0).abs() < 1e-3, "row sum {row}");
            }
        }
    }
}

#[test]
fn first_doc_prefill_matches_joint_prefill() {
    let Some((_rt, model, sample)) = setup() else { return };
    let cfg = model.cfg.clone();
    let (tokens, valid, _) = assemble_full(&sample, &cfg);
    let kv_full = model.prefill_full(&tokens, &valid).unwrap();
    let doc = model.prefill_doc(&sample.docs[0], 0).unwrap();
    // doc 1 occupies slots 0..Ld at identical positions in both layouts
    let mut max_err = 0f32;
    for l in 0..cfg.n_layers {
        for kv in 0..2 {
            for h in 0..cfg.n_heads {
                for s in 0..cfg.doc_len {
                    let a = kv_full.slice_at(&[l, kv, h, s]);
                    let b = doc.kv.slice_at(&[l, kv, h, s]);
                    for (x, y) in a.iter().zip(b) {
                        max_err = max_err.max((x - y).abs());
                    }
                }
            }
        }
    }
    assert!(max_err < 2e-3, "max err {max_err}");
}

#[test]
fn recompute_everything_recovers_joint_prefill() {
    let Some((_rt, model, sample)) = setup() else { return };
    let cfg = model.cfg.clone();
    let (tokens, valid, _) = assemble_full(&sample, &cfg);
    let kv_full = model.prefill_full(&tokens, &valid).unwrap();
    let lt = cfg.full_len;
    let kv_junk = Tensor::zeros(&[cfg.n_layers, 2, cfg.n_heads, lt,
                                  cfg.head_dim]);
    let positions: Vec<i32> = (0..lt as i32).collect();
    let rec = Tensor::full(&[cfg.n_layers, lt], 1.0);
    let kv_out = model
        .recompute(Buffer::Full, &tokens, &positions, &kv_junk, rec, &valid)
        .unwrap();
    let mut max_err = 0f32;
    for (i, (a, b)) in kv_out.data().iter().zip(kv_full.data()).enumerate() {
        // only compare valid slots
        let s = (i / cfg.head_dim) % lt;
        if valid[s] > 0.0 {
            max_err = max_err.max((a - b).abs());
        }
    }
    assert!(max_err < 2e-3, "max err {max_err}");
}

#[test]
fn decode_returns_cache_consistent_kv() {
    let Some((_rt, model, sample)) = setup() else { return };
    let cfg = model.cfg.clone();
    let (tokens, valid, ans_start) = assemble_full(&sample, &cfg);
    let kv_full = model.prefill_full(&tokens, &valid).unwrap();
    let last = ans_start - 1; // ANS token slot
    let kv_valid: Vec<f32> = (0..cfg.full_len)
        .map(|i| if i < last { 1.0 } else { 0.0 })
        .collect();
    let out = model
        .decode(Buffer::Full, tokens[last], last as i32, last as i32,
                &kv_full, &kv_valid)
        .unwrap();
    assert_eq!(out.logits.len(), cfg.vocab);
    // decode recomputes the ANS token's K/V — must match the joint prefill
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            let k_cache = kv_full.slice_at(&[l, 0, h, last]);
            let k_new = out.k_new.slice_at(&[l, h]);
            for (a, b) in k_cache.iter().zip(k_new) {
                assert!((a - b).abs() < 2e-3);
            }
        }
    }
}

#[test]
fn runtime_stats_accumulate() {
    let Some((rt, model, sample)) = setup() else { return };
    rt.reset_stats();
    let _ = model.prefill_doc(&sample.docs[0], 0).unwrap();
    let _ = model.prefill_doc(&sample.docs[1], 0).unwrap();
    let stats = rt.stats();
    let (name, s) = stats
        .iter()
        .find(|(n, _)| n == "tiny:prefill_doc")
        .expect("stats entry");
    assert_eq!(name, "tiny:prefill_doc");
    assert_eq!(s.calls, 2);
    assert!(s.total_ms > 0.0);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some((_rt, model, _sample)) = setup() else { return };
    let bad = vec![1i32; 3];
    assert!(model.prefill_doc(&bad, 0).is_err());
}
