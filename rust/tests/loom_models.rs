//! Concurrency models for the serving stack's core protocols, run
//! through [`samkv::sync::model`]:
//!
//! * under `RUSTFLAGS="--cfg loom"` each test body is a **loom model**
//!   — every interleaving of the participating threads is explored
//!   exhaustively (bound with `LOOM_MAX_PREEMPTIONS`), so the
//!   assertions are checked against schedules a stress test would
//!   need astronomically many runs to hit;
//! * in a normal build the same bodies run as bounded stress loops
//!   with real threads (`SAMKV_MODEL_ITERS` iterations, default 64),
//!   so `cargo test` still exercises them.
//!
//! The four protocols modeled (see `crate::sync`'s module docs for the
//! lock classes involved):
//!
//! 1. **Exactly-once prefill leasing** — two racing threads ask the
//!    host tier for the same unpublished document; exactly one gets
//!    the [`HostLookup::Miss`] lease, the other blocks on the publish
//!    condvar and is served the published entry as a hit.
//! 2. **Block refcount safety** — concurrent clone / CoW-write / drop
//!    of a shared pool block never double-frees a slot, never lets a
//!    write through a shared ref clobber the other holder's payload,
//!    and returns the pool to fully-free at the end.
//! 3. **Gate permit conservation** — concurrent take/release (and an
//!    untimed waiter) neither mint nor leak admission permits.
//! 4. **Breaker probe race** — racing probes against one
//!    open-past-interval [`BreakerCore`] observe the
//!    open → half-open → closed walk with the close reported exactly
//!    once, and a failed probe re-opens exactly once.
//!
//! Every shared structure lives behind the [`samkv::sync`] facade, so
//! the loom build swaps the real `std::sync` primitives for loom's
//! model-checked ones without touching production code. All state is
//! created inside the model closure: loom re-runs it per schedule.

use std::time::Duration;

use samkv::exec::Gate;
use samkv::kvcache::pool::BlockRef;
use samkv::kvcache::store::HostLookup;
use samkv::kvcache::{
    doc_hash, BreakerCore, BreakerStep, DocEntry, HostDocCache,
    KvBlockPool,
};
use samkv::sync::atomic::{AtomicUsize, Ordering};
use samkv::sync::{self, thread, Arc, Mutex};
use samkv::tensor::Tensor;

/// The smallest publishable document: `[L=1, 2, H=1, T=1, Dh=2]` KV
/// (one pool block), `[1,1,1,1]` attention, `[1,1,2]` local-mean Q.
fn tiny_entry(host: &HostDocCache, tokens: &[i32]) -> Arc<DocEntry> {
    let kv = Tensor::zeros(&[1, 2, 1, 1, 2]);
    let attn = Tensor::zeros(&[1, 1, 1, 1]);
    let q_local = Tensor::zeros(&[1, 1, 2]);
    let entry =
        DocEntry::from_parts(host.pool(), tokens.to_vec(), kv, attn, q_local)
            .expect("tiny entry must build");
    Arc::new(entry)
}

/// Model 1: exactly-once lease publication under racing prefillers.
///
/// Two threads race `lookup_or_begin` on one unpublished hash. The
/// exactly-once contract: exactly one thread observes the miss and
/// prefills (here: builds [`tiny_entry`]); the other is served that
/// publish as a hit — either immediately or after waiting on the
/// publish condvar — and the host tier records exactly one miss, one
/// hit, one publish.
#[test]
fn lease_publishes_exactly_once_under_race() {
    sync::model(|| {
        let host = Arc::new(HostDocCache::unbounded());
        let tokens: Vec<i32> = vec![7]; // one token: matches the KV's T=1
        let hash = doc_hash(&tokens);
        let misses = Arc::new(AtomicUsize::new(0));

        let handles: Vec<_> = (0..2)
            .map(|_| {
                let host = Arc::clone(&host);
                let tokens = tokens.clone();
                let misses = Arc::clone(&misses);
                thread::spawn(move || {
                    match HostDocCache::lookup_or_begin(
                        &host, hash, &tokens,
                    ) {
                        HostLookup::Miss(lease) => {
                            misses.fetch_add(1, Ordering::SeqCst);
                            assert!(
                                lease.partial().is_none(),
                                "nothing published yet, so the lease \
                                 cannot carry a partial entry"
                            );
                            lease.publish(tiny_entry(&host, &tokens));
                        }
                        HostLookup::Hit(entry) => {
                            // served the *other* thread's publish
                            assert_eq!(entry.tokens, tokens);
                            assert!(entry.kv.is_fully_resident());
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("lease racer must not panic");
        }

        assert_eq!(
            misses.load(Ordering::SeqCst),
            1,
            "exactly one racer may win the prefill lease"
        );
        let stats = host.stats();
        assert_eq!(stats.misses, 1, "one miss: the lease holder's");
        assert_eq!(stats.hits, 1, "one hit: the other racer's");
        assert_eq!(stats.publishes, 1, "one publish: the lease's");
        assert!(
            host.try_lookup(hash, &tokens).is_some(),
            "the published entry must be servable afterwards"
        );
    });
}

/// Model 2: no double-free / use-after-free under concurrent
/// clone / CoW-write / drop of one shared block.
///
/// Three holders of one slot: the main thread's original, a cloner
/// (pin/share path), and a writer (CoW path). Whatever the schedule,
/// the pool must never count a double-free, the writer's private copy
/// must never clobber the payload the other holders read, and once
/// every ref drops the pool is fully free again.
#[test]
fn blockref_clone_write_drop_race_is_safe() {
    sync::model(|| {
        let pool = Arc::new(KvBlockPool::new(1));
        let base =
            BlockRef::alloc(&pool, 2, &[1.0, 2.0]).expect("alloc");

        let cloner = {
            let r = base.clone();
            thread::spawn(move || {
                let pinned = r.clone(); // pin: second ref, then drop
                let mut out = [0f32; 2];
                pinned.read(0, &mut out).expect("read via clone");
                assert_eq!(
                    out,
                    [1.0, 2.0],
                    "sharers must never observe the CoW writer's data"
                );
            })
        };
        let writer = {
            let mut r = base.clone();
            thread::spawn(move || {
                // CoW: with the slot shared this must move `r` to a
                // private slot and leave the original payload alone
                r.write(0, &[9.0, 9.0]).expect("CoW write");
                let mut out = [0f32; 2];
                r.read(0, &mut out).expect("read own copy");
                assert_eq!(out, [9.0, 9.0]);
            })
        };
        cloner.join().expect("cloner must not panic");
        writer.join().expect("writer must not panic");

        let mut out = [0f32; 2];
        base.read(0, &mut out).expect("original still live");
        assert_eq!(out, [1.0, 2.0], "original payload intact after CoW");
        drop(base);

        let stats = pool.stats();
        assert_eq!(stats.double_frees, 0, "no release may double-free");
        assert_eq!(stats.slots_live, 0, "every ref dropped ⇒ none live");
        assert_eq!(
            stats.slots_free, stats.slots_total,
            "all slots must return to the free list"
        );
    });
}

/// Model 3: Gate permit conservation.
///
/// Two takers debit and credit one permit each while a waiter blocks
/// for a free slot (untimed under loom — the releases guarantee it
/// wakes). No schedule may mint permits (observe more than the cap)
/// or leak them (end below the cap).
#[test]
fn gate_conserves_permits_under_race() {
    sync::model(|| {
        const SLOTS: usize = 2;
        let gate = Arc::new(Gate::new(SLOTS));

        let takers: Vec<_> = (0..2)
            .map(|_| {
                let gate = Arc::clone(&gate);
                thread::spawn(move || {
                    gate.take(1);
                    assert!(
                        gate.available() <= SLOTS,
                        "a debit can never leave more than the cap free"
                    );
                    gate.release(1);
                    assert!(gate.available() <= SLOTS);
                })
            })
            .collect();
        let waiter = {
            let gate = Arc::clone(&gate);
            thread::spawn(move || {
                // both takers release what they took, so the free
                // count is eventually non-zero: the wait always wakes
                let n = gate.wait_available(Duration::from_secs(5));
                assert!(
                    (1..=SLOTS).contains(&n),
                    "waiter observed {n} free slots (cap {SLOTS})"
                );
            })
        };
        for t in takers {
            t.join().expect("taker must not panic");
        }
        waiter.join().expect("waiter must not panic");

        assert_eq!(
            gate.available(),
            SLOTS,
            "all permits must be back after every take was released"
        );
    });
}

/// Model 4a: breaker open → half-open → close under racing probes.
///
/// The breaker starts open, past its probe interval. Two probe
/// threads each run the disk tier's per-operation protocol — gate
/// with `blocks(now)`, then report `note_ok()` — under the one
/// breaker lock (class `disk-index` in production). In every
/// schedule the first gate call flips open → half-open, no probe is
/// short-circuited, and **exactly one** `note_ok` reports the
/// half-open → closed transition (the metrics/log edge trigger).
#[test]
fn breaker_racing_ok_probes_close_exactly_once() {
    sync::model(|| {
        let mut core = BreakerCore::new(1, 5);
        assert_eq!(
            core.note_error(0),
            BreakerStep::Opened { failed_probe: false },
            "threshold 1: the seed error must open the breaker"
        );
        let breaker = Arc::new(Mutex::named("loom-breaker", core));
        let closes = Arc::new(AtomicUsize::new(0));

        let probes: Vec<_> = (0..2)
            .map(|_| {
                let breaker = Arc::clone(&breaker);
                let closes = Arc::clone(&closes);
                thread::spawn(move || {
                    // gate (own lock scope, like the disk tier's)
                    let admitted = !breaker.lock().blocks(10);
                    assert!(
                        admitted,
                        "open-past-interval must admit every prober \
                         (first flips to half-open, rest see it)"
                    );
                    // the probed operation succeeds; report it
                    if breaker.lock().note_ok() {
                        closes.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for p in probes {
            p.join().expect("probe must not panic");
        }

        assert_eq!(
            closes.load(Ordering::SeqCst),
            1,
            "exactly one probe success may report the close"
        );
        let mut g = breaker.lock();
        assert!(!g.is_tripped(), "breaker must end closed");
        assert!(!g.blocks(11), "closed breaker must not block");
    });
}

/// Model 4b: a failed probe re-opens exactly once under racing
/// error probes.
///
/// Same start (open past interval), but both admitted probes fail.
/// Whatever the interleaving of gate and report calls, exactly one
/// `note_error` reports `Opened { failed_probe: true }` — the other
/// either finds the breaker already re-opened (`NoChange`) or was
/// short-circuited by the fresh open interval and reports nothing.
#[test]
fn breaker_racing_failed_probes_reopen_exactly_once() {
    sync::model(|| {
        let mut core = BreakerCore::new(1, 5);
        assert_eq!(
            core.note_error(0),
            BreakerStep::Opened { failed_probe: false }
        );
        let breaker = Arc::new(Mutex::named("loom-breaker", core));
        let reopens = Arc::new(AtomicUsize::new(0));

        let probes: Vec<_> = (0..2)
            .map(|_| {
                let breaker = Arc::clone(&breaker);
                let reopens = Arc::clone(&reopens);
                thread::spawn(move || {
                    // now=10 is past the first open's interval but
                    // inside a re-open at now=10, so a probe gated
                    // after the other's failure is short-circuited
                    let admitted = !breaker.lock().blocks(10);
                    if admitted
                        && breaker.lock().note_error(10)
                            == (BreakerStep::Opened { failed_probe: true })
                    {
                        reopens.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for p in probes {
            p.join().expect("probe must not panic");
        }

        assert_eq!(
            reopens.load(Ordering::SeqCst),
            1,
            "exactly one failed probe may report the re-open"
        );
        let mut g = breaker.lock();
        assert!(g.is_tripped(), "breaker must end open");
        assert!(
            g.blocks(12),
            "re-opened at 10 with a 5ms interval: 12 is inside it"
        );
    });
}
