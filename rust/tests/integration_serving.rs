//! Serving-stack integration: engine thread + router + TCP server +
//! client, over the tiny artifacts. No-ops when artifacts are missing.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use samkv::config::ServingConfig;
use samkv::coordinator::{Engine, ServeRequest};
use samkv::kvcache::HostDocCache;
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::server::{Client, Server};
use samkv::workload::Dataset;

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

/// Single engine over a private host tier (the pre-tier spawn shape).
fn spawn_one(policy: &str, metrics: &Arc<Metrics>) -> Engine {
    Engine::spawn(0, artifacts_dir(), tiny_cfg(), policy.to_string(),
                  Arc::clone(metrics),
                  Arc::new(HostDocCache::unbounded()), None)
        .unwrap()
}

#[test]
fn engine_serves_requests_from_channel() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("SamKV-fusion", &metrics);
    let h = engine.handle();
    let resp = h
        .serve(ServeRequest {
            id: 11,
            sample: ds.samples[0].clone(),
            policy: String::new(), // default policy
            stream: false,
        })
        .unwrap();
    assert_eq!(resp.id, 11);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed)
            == 1);

    // unknown policy is rejected, not crashed
    let resp = h
        .serve(ServeRequest {
            id: 12,
            sample: ds.samples[0].clone(),
            policy: "NoSuchPolicy".to_string(),
            stream: false,
        })
        .unwrap();
    assert!(resp.error.is_some());
}

#[test]
fn engine_parallel_submitters() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("Reuse", &metrics);
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let h = engine.handle();
            let s = ds.samples[i % ds.samples.len()].clone();
            thread::spawn(move || {
                h.serve(ServeRequest { id: i as u64, sample: s,
                                       policy: String::new(),
                                       stream: false })
                    .unwrap()
            })
        })
        .collect();
    for t in handles {
        let r = t.join().unwrap();
        assert!(r.error.is_none());
    }
    assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
               6);
}

#[test]
fn batch_dedups_shared_doc_prefill() {
    // two requests over the SAME document set must trigger exactly one
    // prefill per unique document (the tier-backed doc_prefills
    // counter proves it), and — when the two land in one batch window —
    // batch-level dedup must split the shared prefill cost across both
    // (both cold, both credited), not leave request 2 a store hit.
    // Batching is timing-dependent (2ms gather window), so retry with
    // fresh documents until a same-batch pair is observed.
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("Reuse", &metrics);
    let h = engine.handle();
    let mut saw_same_batch = false;
    for attempt in 0..25 {
        // unique doc contents per attempt (cold store every time)
        let mut s = ds.samples[0].clone();
        for d in &mut s.docs {
            d[1] = samkv::tokenizer::filler_tok(attempt);
            d[2] = samkv::tokenizer::filler_tok(
                samkv::tokenizer::N_FILLERS - 1 - attempt);
        }
        // keep the engine busy with a warmup request (distinct docs) so
        // the pair below queues together and co-batches deterministically
        let mut w = ds.samples[0].clone();
        for d in &mut w.docs {
            d[3] = samkv::tokenizer::filler_tok(50 + attempt);
        }
        // expected fresh prefills this attempt: the unique documents
        // across the warmup and the (shared) pair
        let uniq: std::collections::HashSet<u64> = w
            .docs
            .iter()
            .chain(s.docs.iter())
            .map(|d| samkv::kvcache::store::doc_hash(d))
            .collect();
        let expected = uniq.len() as u64;
        let before = metrics.doc_prefills
            .load(std::sync::atomic::Ordering::Relaxed);
        let rxw = h
            .submit(ServeRequest { id: 99, sample: w,
                                   policy: String::new(), stream: false })
            .unwrap();
        // submit both before receiving so they share a batch window
        let rx1 = h
            .submit(ServeRequest { id: 1, sample: s.clone(),
                                   policy: String::new(), stream: false })
            .unwrap();
        let rx2 = h
            .submit(ServeRequest { id: 2, sample: s,
                                   policy: String::new(), stream: false })
            .unwrap();
        let _ = samkv::coordinator::recv_done(&rxw).unwrap();
        let r1 = samkv::coordinator::recv_done(&rx1).unwrap();
        let r2 = samkv::coordinator::recv_done(&rx2).unwrap();
        assert!(r1.error.is_none() && r2.error.is_none());
        assert_eq!(r1.answer, r2.answer, "shared prefill changed results");
        // regardless of batching: each unique doc prefilled exactly once
        let delta = metrics.doc_prefills
            .load(std::sync::atomic::Ordering::Relaxed) - before;
        assert_eq!(delta, expected,
                   "attempt {attempt}: docs prefilled more than once \
                    across the warmup + shared pair");
        assert!(r1.stats.doc_prefill_ms > 0.0);
        // same-batch signature: request 2 was NOT served from a warm
        // store (that would mean a later batch) — batch dedup credited
        // it a share of the one shared prefill instead
        if !r2.stats.cache_warm {
            assert!(!r1.stats.cache_warm);
            assert!(r2.stats.doc_prefill_ms > 0.0,
                    "same-batch request got no shared-prefill credit");
            saw_same_batch = true;
            break;
        }
    }
    assert!(saw_same_batch,
            "two back-to-back submits never shared a batch in 25 tries");
}

#[test]
fn engine_streams_tokens_before_done() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("SamKV-fusion", &metrics);
    let rx = engine
        .handle()
        .submit(ServeRequest { id: 9, sample: ds.samples[0].clone(),
                               policy: String::new(), stream: true })
        .unwrap();
    let mut streamed = Vec::new();
    let resp = loop {
        match rx.recv().unwrap() {
            samkv::coordinator::ServeEvent::Token { id, index, token } => {
                assert_eq!(id, 9);
                assert_eq!(index, streamed.len(), "tokens out of order");
                streamed.push(token);
            }
            samkv::coordinator::ServeEvent::Done(r) => break r,
        }
    };
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(streamed, resp.answer,
               "streamed tokens must equal the final answer");
    assert!(resp.stats.plan_ms >= 0.0);
}

#[test]
fn tcp_server_end_to_end() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("SamKV-fusion", &metrics);
    let handles = vec![engine.handle()];
    let server = Server::new(handles, metrics);
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let port = port_rx.recv().unwrap();
    let addr = format!("127.0.0.1:{port}");

    let mut client = Client::connect(&addr).unwrap();
    let s = &ds.samples[0];
    let resp = client.request(&s.docs, &s.query, "Reuse").unwrap();
    assert!(resp.get("error").is_none(), "{resp}");
    assert!(resp.get("answer").unwrap().as_arr().is_some());
    assert!(resp.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

    // second request on the same connection hits the doc cache
    let resp2 = client.request(&s.docs, &s.query, "Reuse").unwrap();
    assert_eq!(resp2.get("cache_warm").unwrap().as_bool(), Some(true));
    // same answer with warm cache
    assert_eq!(resp.get("answer").unwrap(), resp2.get("answer").unwrap());

    // streaming over the wire: token lines precede the terminal line
    let mut streamed = Vec::new();
    let resp3 = client
        .request_stream(&s.docs, &s.query, "Reuse", |t| streamed.push(t))
        .unwrap();
    assert!(resp3.get("error").is_none(), "{resp3}");
    let final_answer: Vec<i32> = resp3
        .get("answer").unwrap().i32_vec().unwrap();
    assert_eq!(streamed, final_answer);
    assert!(resp3.get("plan_ms").unwrap().as_f64().is_some());
    assert!(resp3.get("doc_prefill_ms").unwrap().as_f64().is_some());

    let m = client.metrics().unwrap();
    assert!(m.get("report").unwrap().as_str().unwrap()
        .contains("completed=3"));

    client.shutdown().unwrap();
    srv.join().unwrap().unwrap();
}

#[test]
fn malformed_request_returns_error_line() {
    let Some(_ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = spawn_one("Reuse", &metrics);
    let server = Server::new(vec![engine.handle()], metrics);
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let port = port_rx.recv().unwrap();

    use std::io::{BufRead, BufReader, Write};
    let stream =
        std::net::TcpStream::connect(format!("127.0.0.1:{port}")).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(w, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    srv.join().unwrap().unwrap();
}
