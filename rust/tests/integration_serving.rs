//! Serving-stack integration: engine thread + router + TCP server +
//! client, over the tiny artifacts. No-ops when artifacts are missing.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use samkv::config::ServingConfig;
use samkv::coordinator::{Engine, ServeRequest};
use samkv::metrics::Metrics;
use samkv::runtime::artifacts_dir;
use samkv::server::{Client, Server};
use samkv::workload::Dataset;

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn tiny_cfg() -> ServingConfig {
    ServingConfig { profile: "tiny".to_string(), ..ServingConfig::default() }
}

#[test]
fn engine_serves_requests_from_channel() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "SamKV-fusion".to_string(),
                               Arc::clone(&metrics))
        .unwrap();
    let h = engine.handle();
    let resp = h
        .serve(ServeRequest {
            id: 11,
            sample: ds.samples[0].clone(),
            policy: String::new(), // default policy
        })
        .unwrap();
    assert_eq!(resp.id, 11);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed)
            == 1);

    // unknown policy is rejected, not crashed
    let resp = h
        .serve(ServeRequest {
            id: 12,
            sample: ds.samples[0].clone(),
            policy: "NoSuchPolicy".to_string(),
        })
        .unwrap();
    assert!(resp.error.is_some());
}

#[test]
fn engine_parallel_submitters() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "Reuse".to_string(), Arc::clone(&metrics))
        .unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let h = engine.handle();
            let s = ds.samples[i % ds.samples.len()].clone();
            thread::spawn(move || {
                h.serve(ServeRequest { id: i as u64, sample: s,
                                       policy: String::new() })
                    .unwrap()
            })
        })
        .collect();
    for t in handles {
        let r = t.join().unwrap();
        assert!(r.error.is_none());
    }
    assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
               6);
}

#[test]
fn tcp_server_end_to_end() {
    let Some(ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "SamKV-fusion".to_string(),
                               Arc::clone(&metrics))
        .unwrap();
    let handles = vec![engine.handle()];
    let server = Server::new(handles, metrics);
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let port = port_rx.recv().unwrap();
    let addr = format!("127.0.0.1:{port}");

    let mut client = Client::connect(&addr).unwrap();
    let s = &ds.samples[0];
    let resp = client.request(&s.docs, &s.query, "Reuse").unwrap();
    assert!(resp.get("error").is_none(), "{resp}");
    assert!(resp.get("answer").unwrap().as_arr().is_some());
    assert!(resp.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);

    // second request on the same connection hits the doc cache
    let resp2 = client.request(&s.docs, &s.query, "Reuse").unwrap();
    assert_eq!(resp2.get("cache_warm").unwrap().as_bool(), Some(true));
    // same answer with warm cache
    assert_eq!(resp.get("answer").unwrap(), resp2.get("answer").unwrap());

    let m = client.metrics().unwrap();
    assert!(m.get("report").unwrap().as_str().unwrap()
        .contains("completed=2"));

    client.shutdown().unwrap();
    srv.join().unwrap().unwrap();
}

#[test]
fn malformed_request_returns_error_line() {
    let Some(_ds) = ready() else { return };
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::spawn(0, artifacts_dir(), tiny_cfg(),
                               "Reuse".to_string(), Arc::clone(&metrics))
        .unwrap();
    let server = Server::new(vec![engine.handle()], metrics);
    let (port_tx, port_rx) = mpsc::channel();
    let srv = thread::spawn(move || {
        server.run("127.0.0.1:0", move |p| {
            port_tx.send(p).unwrap();
        })
    });
    let port = port_rx.recv().unwrap();

    use std::io::{BufRead, BufReader, Write};
    let stream =
        std::net::TcpStream::connect(format!("127.0.0.1:{port}")).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(w, "{{\"cmd\":\"shutdown\"}}").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    srv.join().unwrap().unwrap();
}
