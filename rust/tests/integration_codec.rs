//! Codec-layer integration over the tiny artifacts: every paper
//! policy served through a host+disk stack built with each KV codec.
//! The lossless contract is token-level — `--kv-codec f32` must be
//! byte-identical to a stack with no codec configured for all 7
//! policies. The lossy codecs (f16, int8) have no token-equality
//! contract (quantization may legitimately move an argmax), so their
//! tolerance is functional: every policy serves error-free, the
//! encoded path is deterministic (two serves over the same stack are
//! token-identical), and the compression envelope holds (physical vs
//! logical bytes >=1.9x for f16, >=3.5x for int8). A final test
//! downgrades a really-served disk directory to the legacy v2 format
//! and warm-restarts an int8-configured stack over it: v2 records are
//! untagged raw f32, so the restart must serve with zero prefills and
//! token-identical output.
//!
//! Tests no-op when artifacts aren't built.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use samkv::config::{DiskWriteback, KvCodecKind, ServingConfig};
use samkv::coordinator::{Engine, Router, ServeRequest};
use samkv::kvcache::{codec_for, doc_hash, DiskDocCache, HostDocCache};
use samkv::metrics::Metrics;
use samkv::policies::all_policies;
use samkv::runtime::artifacts_dir;
use samkv::workload::{Dataset, Sample};

fn ready() -> Option<Dataset> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Dataset::load(dir.join("datasets/d2x32_hotpot-sim.json")).unwrap())
}

fn policy_names() -> Vec<String> {
    let names: Vec<String> =
        all_policies().iter().map(|p| p.name()).collect();
    assert_eq!(names.len(), 7, "the paper table has 7 policies");
    names
}

/// One complete serving stack (fresh metrics, one engine, host tier
/// built with `codec`/`hot_blocks`, optional write-through disk tier
/// sharing the same codec instance). The engine stays up so multiple
/// policies can be served through one stack.
struct Stack {
    engine: Engine,
    metrics: Arc<Metrics>,
    disk: Option<Arc<DiskDocCache>>,
    next_id: u64,
}

impl Stack {
    fn build(dir: Option<&PathBuf>, codec: KvCodecKind,
             hot_blocks: usize) -> Stack {
        let metrics = Arc::new(Metrics::new());
        let c = codec_for(codec);
        let mut host =
            HostDocCache::unbounded().with_codec(Arc::clone(&c), hot_blocks);
        let mut disk_handle = None;
        if let Some(dir) = dir {
            let disk = Arc::new(DiskDocCache::open(dir, usize::MAX)
                .unwrap()
                .with_codec(Arc::clone(&c)));
            disk_handle = Some(Arc::clone(&disk));
            host = host.with_disk(disk, DiskWriteback::Through);
        }
        let cfg = ServingConfig {
            profile: "tiny".to_string(),
            kv_codec: codec,
            kv_hot_blocks: hot_blocks,
            ..ServingConfig::default()
        };
        let router = Arc::new(Router::new(1));
        let engine = Engine::spawn(0, artifacts_dir(), cfg,
                                   "Reuse".to_string(),
                                   Arc::clone(&metrics), Arc::new(host),
                                   Some(router.residency_handle(0)))
            .unwrap();
        Stack { engine, metrics, disk: disk_handle, next_id: 1 }
    }

    /// Baseline stack: no codec configured at all — "today's output".
    fn plain() -> Stack {
        let metrics = Arc::new(Metrics::new());
        let cfg = ServingConfig { profile: "tiny".to_string(),
                                  ..ServingConfig::default() };
        let router = Arc::new(Router::new(1));
        let engine = Engine::spawn(0, artifacts_dir(), cfg,
                                   "Reuse".to_string(),
                                   Arc::clone(&metrics),
                                   Arc::new(HostDocCache::unbounded()),
                                   Some(router.residency_handle(0)))
            .unwrap();
        Stack { engine, metrics, disk: None, next_id: 1 }
    }

    fn serve(&mut self, sample: &Sample, policy: &str) -> Vec<i32> {
        let id = self.next_id;
        self.next_id += 1;
        let resp = self
            .engine
            .handle()
            .serve(ServeRequest {
                id,
                sample: sample.clone(),
                policy: policy.to_string(),
                stream: false,
            })
            .unwrap();
        assert!(resp.error.is_none(), "policy {policy}: {:?}", resp.error);
        assert!(!resp.answer.is_empty(), "policy {policy}: empty answer");
        resp.answer
    }
}

#[test]
fn f32_codec_is_token_identical_for_all_policies() {
    let Some(ds) = ready() else { return };
    let sample = ds.samples[0].clone();
    let mut plain = Stack::plain();
    // hot_blocks = 0: the most codec-exposed configuration. The f32
    // codec keeps every block pooled by design (see
    // `KvBlockPool::is_encoded`), so this asserts that configuring it
    // changes nothing at all about the served tokens
    let mut f32s = Stack::build(None, KvCodecKind::F32, 0);
    for policy in policy_names() {
        let base = plain.serve(&sample, &policy);
        let coded = f32s.serve(&sample, &policy);
        assert_eq!(coded, base,
                   "f32 codec must be token-identical ({policy})");
    }
}

#[test]
fn lossy_codecs_serve_all_policies_deterministically() {
    let Some(ds) = ready() else { return };
    let sample = ds.samples[0].clone();
    for (kind, min_ratio) in
        [(KvCodecKind::F16, 1.9), (KvCodecKind::Int8, 3.5)]
    {
        let mut stack = Stack::build(None, kind, 0);
        let mut first: Vec<Vec<i32>> = Vec::new();
        for policy in policy_names() {
            first.push(stack.serve(&sample, &policy));
        }
        // second pass over a warm cache: the encoded blocks were
        // quantized exactly once at admission, so decode-on-assemble
        // must reproduce the same tokens
        for (i, policy) in policy_names().iter().enumerate() {
            let again = stack.serve(&sample, policy);
            assert_eq!(again, first[i],
                       "encoded path must be deterministic ({policy})");
        }
        // the codec demonstrably engaged, and within its envelope
        let enc = stack.metrics.codec_blocks_encoded.load(Ordering::Relaxed);
        let dec = stack.metrics.codec_blocks_decoded.load(Ordering::Relaxed);
        assert!(enc > 0, "{}: no blocks encoded", kind.name());
        assert!(dec > 0, "{}: no blocks decoded", kind.name());
        let ratio = stack.metrics.codec_compression_ratio();
        assert!(ratio >= min_ratio,
                "{}: compression ratio {ratio:.2} < {min_ratio}",
                kind.name());
        assert!(stack.metrics.report().contains(&format!(
            "codec({}", kind.name())));
    }
}

#[test]
fn warm_restart_loads_v2_files_into_int8_cache() {
    let Some(ds) = ready() else { return };
    let sample = ds.samples[0].clone();
    let n_unique = sample
        .docs
        .iter()
        .map(|d| doc_hash(d))
        .collect::<std::collections::HashSet<_>>()
        .len() as u64;
    let dir = std::env::temp_dir().join(format!(
        "samkv-itest-codec-v2-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // cold process: lossless stack spills every unique doc to disk
    let cold_answer = {
        let mut cold = Stack::build(Some(&dir), KvCodecKind::F32, 0);
        let answer = cold.serve(&sample, "Reuse");
        assert_eq!(cold.disk.as_ref().unwrap().stats().spills, n_unique);
        answer
        // full stack teardown: only the files remain
    };

    // downgrade the directory to the legacy v2 format in place
    let mut rewritten = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().map(|x| x == "kv").unwrap_or(false) {
            samkv::kvcache::disk::rewrite_file_as_v2(&path).unwrap();
            let bytes = std::fs::read(&path).unwrap();
            assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6],
                                           bytes[7]]),
                       2, "downgraded file must be version 2");
            rewritten += 1;
        }
    }
    assert_eq!(rewritten, n_unique, "every spilled file downgraded");

    // "restarted" process with an int8-configured cache: v2 records
    // are untagged raw f32 and the tiny docs fit inside the default
    // hot watermark, so the warm answers must be token-identical
    {
        let mut warm = Stack::build(
            Some(&dir), KvCodecKind::Int8,
            ServingConfig::default().kv_hot_blocks);
        let answer = warm.serve(&sample, "Reuse");
        assert_eq!(answer, cold_answer,
                   "v2 files must load losslessly into an int8 cache");
        assert_eq!(warm.metrics.doc_prefills.load(Ordering::Relaxed), 0,
                   "warm restart must serve off disk, not re-prefill");
        let s = warm.disk.as_ref().unwrap().stats();
        assert!(s.hits >= n_unique);
        assert_eq!((s.corrupt, s.corrupt_blocks), (0, 0));
        assert!(s.bytes_loaded > 0, "restart reads real file bytes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
