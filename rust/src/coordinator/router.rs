//! Cache-aware, affinity-backed router (the vLLM-router shape).
//!
//! Placement order for a request:
//! 1. **Residency** — the engine already holding the most of the
//!    request's document hashes device-resident (read from the shared
//!    [`ResidencyBoard`] that every engine's residency tier updates)
//!    wins, so the request lands where its KV already lives.
//! 2. **Affinity** — otherwise the combined doc-set hash picks a
//!    stable engine, so recurring doc-sets keep warming one cache.
//! 3. **Least-loaded** — either preference is overridden when the
//!    preferred engine's in-flight load exceeds the minimum by more
//!    than `imbalance_limit`.
//!
//! A bad placement is never incorrect — the shared host tier still
//! dedups prefill work across engines — it just costs residency churn.
//!
//! **Engine supervision:** the router also carries a per-engine down
//! state ([`Router::mark_down`], fed by the engine's `decode_alive`
//! flag via the server). A down engine is excluded from every
//! placement stage and its residency advertisements are cleared, so
//! retried requests land on survivors; if *every* engine is down the
//! filter falls back to all engines (the submit path then surfaces the
//! failure as a structured error instead of a panic here).

use std::sync::Arc;

use crate::kvcache::store::doc_hash;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::kvcache::{ResidencyBoard, ResidencyHandle};
use crate::workload::Sample;

pub struct Router {
    in_flight: Vec<AtomicU64>,
    /// Allowed load gap before a preference is overridden.
    pub imbalance_limit: u64,
    board: Arc<ResidencyBoard>,
    /// Engines whose decode thread is known dead (placement excluded).
    down: Vec<AtomicBool>,
}

impl Router {
    pub fn new(n_engines: usize) -> Router {
        assert!(n_engines > 0);
        Router {
            in_flight: (0..n_engines).map(|_| AtomicU64::new(0)).collect(),
            imbalance_limit: 8,
            board: Arc::new(ResidencyBoard::new(n_engines)),
            down: (0..n_engines).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    /// Mark `engine` down: it stops receiving placements and its
    /// residency advertisements are cleared. Returns `true` the first
    /// time (callers use this to count the down transition once); an
    /// out-of-range index is a no-op.
    pub fn mark_down(&self, engine: usize) -> bool {
        let Some(down) = self.down.get(engine) else {
            return false;
        };
        let newly = !down.swap(true, Ordering::Relaxed);
        if newly {
            self.board.clear_engine(engine);
        }
        newly
    }

    /// Re-admit `engine` to placement (a restarted/replaced engine).
    pub fn mark_up(&self, engine: usize) {
        if let Some(down) = self.down.get(engine) {
            down.store(false, Ordering::Relaxed);
        }
    }

    pub fn is_down(&self, engine: usize) -> bool {
        self.down
            .get(engine)
            .is_some_and(|d| d.load(Ordering::Relaxed))
    }

    /// Number of engines currently marked down.
    pub fn n_down(&self) -> usize {
        self.down
            .iter()
            .filter(|d| d.load(Ordering::Relaxed))
            .count()
    }

    pub fn n_engines(&self) -> usize {
        self.in_flight.len()
    }

    /// The residency board engines should advertise on.
    pub fn board(&self) -> &Arc<ResidencyBoard> {
        &self.board
    }

    /// Writer handle wiring engine `i`'s residency tier to this
    /// router's board (pass to `Engine::spawn`).
    pub fn residency_handle(&self, engine: usize) -> ResidencyHandle {
        ResidencyHandle::new(Arc::clone(&self.board), engine)
    }

    /// Combined hash of the sample's document set (order-insensitive so
    /// permuted retrievals still hit the same engine cache).
    pub fn affinity_hash(sample: &Sample) -> u64 {
        Self::fold_hashes(
            &sample.docs.iter().map(|d| doc_hash(d)).collect::<Vec<_>>())
    }

    /// The affinity fold over already-computed per-doc hashes — the
    /// single definition [`Self::affinity_hash`] and [`Self::pick`]
    /// share.
    fn fold_hashes(hashes: &[u64]) -> u64 {
        hashes.iter().fold(0u64, |acc, &h| acc ^ h)
    }

    /// Pick an engine; callers must pair with [`Router::done`].
    pub fn pick(&self, sample: &Sample) -> usize {
        let n = self.in_flight.len();
        let loads: Vec<u64> = self
            .in_flight
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect();
        // down engines are excluded from every stage; with all engines
        // down, fall back to all (submit then fails with a structured
        // error rather than pick panicking on an empty candidate set)
        let mut up: Vec<bool> = (0..n).map(|e| !self.is_down(e)).collect();
        if !up.iter().any(|&u| u) {
            up = vec![true; n];
        }
        let min = loads
            .iter()
            .zip(&up)
            .filter(|&(_, &u)| u)
            .map(|(&l, _)| l)
            .min()
            .unwrap_or(0);
        let load_of =
            |e: usize| loads.get(e).copied().unwrap_or(u64::MAX);
        let not_overloaded = |e: usize| {
            up.get(e).copied().unwrap_or(false)
                && load_of(e) <= min + self.imbalance_limit
        };

        // 1) cache-aware: most planned docs already resident wins
        // (ties: lighter load, then lower index — deterministic)
        let hashes: Vec<u64> =
            sample.docs.iter().map(|d| doc_hash(d)).collect();
        let resident = (0..n)
            .map(|e| (self.board.resident_count(e, &hashes), e))
            .filter(|&(c, e)| c > 0 && not_overloaded(e))
            .max_by_key(|&(c, e)| {
                (c, std::cmp::Reverse((load_of(e), e)))
            });

        let chosen = match resident {
            Some((_, e)) => e,
            None => {
                // 2) doc-set affinity (folding the per-doc hashes
                // already computed above), 3) least-loaded fallback
                let preferred =
                    (Self::fold_hashes(&hashes) % n as u64) as usize;
                if not_overloaded(preferred) {
                    preferred
                } else {
                    loads
                        .iter()
                        .enumerate()
                        .filter(|&(e, _)| {
                            up.get(e).copied().unwrap_or(false)
                        })
                        .min_by_key(|&(_, &l)| l)
                        .map(|(i, _)| i)
                        .unwrap_or(preferred)
                }
            }
        };
        if let Some(slot) = self.in_flight.get(chosen) {
            slot.fetch_add(1, Ordering::Relaxed);
        }
        chosen
    }

    /// Release one in-flight slot. Saturates at zero: an unmatched
    /// `done` (double release, error path) must not wrap the load
    /// counter to u64::MAX and poison placement forever.
    pub fn done(&self, engine: usize) {
        if let Some(slot) = self.in_flight.get(engine) {
            let _ = slot.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |v| v.checked_sub(1),
            );
        }
    }

    pub fn loads(&self) -> Vec<u64> {
        self.in_flight
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(doc_seed: i32) -> Sample {
        Sample {
            docs: vec![vec![doc_seed, doc_seed + 1], vec![doc_seed + 2]],
            query: vec![2, 5, 16, 0, 3],
            answer: vec![],
            qtype: "t".into(),
        }
    }

    #[test]
    fn affinity_is_deterministic_and_order_insensitive() {
        let a = sample(10);
        let mut b = sample(10);
        b.docs.reverse();
        assert_eq!(Router::affinity_hash(&a), Router::affinity_hash(&b));
        assert_ne!(Router::affinity_hash(&a),
                   Router::affinity_hash(&sample(11)));
    }

    #[test]
    fn same_docs_same_engine() {
        let r = Router::new(4);
        let s = sample(42);
        let e1 = r.pick(&s);
        r.done(e1);
        let e2 = r.pick(&s);
        r.done(e2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn imbalance_falls_back_to_least_loaded() {
        let mut r = Router::new(2);
        r.imbalance_limit = 2;
        let s = sample(7);
        let preferred = r.pick(&s); // load 1 on preferred
        // pile more load onto the preferred engine
        for _ in 0..4 {
            r.in_flight[preferred].fetch_add(1, Ordering::Relaxed);
        }
        let other = r.pick(&s);
        assert_ne!(other, preferred);
        assert_eq!(r.loads().len(), 2);
    }

    #[test]
    fn least_loaded_tie_breaks_to_lowest_index() {
        let mut r = Router::new(3);
        r.imbalance_limit = 0;
        let s = sample(3);
        let preferred = (Router::affinity_hash(&s) % 3) as usize;
        // overload the affinity engine; all others idle and tied
        r.in_flight[preferred].fetch_add(5, Ordering::Relaxed);
        let chosen = r.pick(&s);
        let expected =
            (0..3).find(|&e| e != preferred).unwrap();
        assert_eq!(chosen, expected,
                   "tied least-loaded must pick the lowest index");
    }

    #[test]
    fn cache_aware_placement_prefers_resident_engine() {
        let r = Router::new(4);
        let s = sample(42);
        let affinity = (Router::affinity_hash(&s) % 4) as usize;
        // some non-affinity engine holds the sample's docs resident
        let resident_engine = (affinity + 1) % 4;
        let h = r.residency_handle(resident_engine);
        for d in &s.docs {
            h.insert(doc_hash(d));
        }
        let chosen = r.pick(&s);
        assert_eq!(chosen, resident_engine,
                   "placement must follow residency over affinity");
        r.done(chosen);
        // partial residency still beats none
        h.remove(doc_hash(&s.docs[0]));
        let chosen = r.pick(&s);
        assert_eq!(chosen, resident_engine);
        r.done(chosen);
        // residency preference yields under overload
        r.in_flight[resident_engine]
            .fetch_add(r.imbalance_limit + 1, Ordering::Relaxed);
        let chosen = r.pick(&s);
        assert_eq!(chosen, affinity,
                   "overloaded resident engine must fall back");
    }

    #[test]
    fn most_resident_engine_wins_ties_by_load() {
        let r = Router::new(2);
        let s = sample(9);
        // engine 0: 1 doc resident; engine 1: both docs resident
        r.residency_handle(0).insert(doc_hash(&s.docs[0]));
        let h1 = r.residency_handle(1);
        h1.insert(doc_hash(&s.docs[0]));
        h1.insert(doc_hash(&s.docs[1]));
        let chosen = r.pick(&s);
        assert_eq!(chosen, 1, "more resident docs must win");
        r.done(chosen);
    }

    #[test]
    fn loads_track_in_flight() {
        let r = Router::new(2);
        let s = sample(1);
        let e = r.pick(&s);
        assert_eq!(r.loads().iter().sum::<u64>(), 1);
        r.done(e);
        assert_eq!(r.loads().iter().sum::<u64>(), 0);
    }

    #[test]
    fn down_engine_never_picked() {
        let r = Router::new(2);
        let s = sample(42);
        // make engine-under-test deterministic: mark down whatever the
        // sample would otherwise prefer
        let preferred = r.pick(&s);
        r.done(preferred);
        assert!(r.mark_down(preferred), "first mark_down reports newly");
        assert!(!r.mark_down(preferred), "second is idempotent");
        assert!(r.is_down(preferred));
        assert_eq!(r.n_down(), 1);
        for _ in 0..8 {
            let e = r.pick(&s);
            assert_ne!(e, preferred, "down engine must not be placed");
            r.done(e);
        }
        r.mark_up(preferred);
        assert_eq!(r.n_down(), 0);
        assert_eq!(r.pick(&s), preferred, "mark_up restores affinity");
        r.done(preferred);
    }

    #[test]
    fn mark_down_clears_residency_and_overload_yields_to_survivor() {
        let r = Router::new(2);
        let s = sample(9);
        let dead = r.pick(&s);
        r.done(dead);
        // dead engine advertises residency AND the survivor is far
        // over the imbalance limit — down-ness must still win
        let h = r.residency_handle(dead);
        for d in &s.docs {
            h.insert(doc_hash(d));
        }
        r.in_flight[1 - dead]
            .fetch_add(r.imbalance_limit + 5, Ordering::Relaxed);
        r.mark_down(dead);
        assert_eq!(r.board().resident_count(dead, &[doc_hash(&s.docs[0])]),
                   0, "mark_down must clear the dead engine's board");
        assert_eq!(r.pick(&s), 1 - dead);
        r.done(1 - dead);
    }

    #[test]
    fn all_down_falls_back_to_all_engines() {
        let r = Router::new(2);
        r.mark_down(0);
        r.mark_down(1);
        let s = sample(3);
        let e = r.pick(&s); // must not panic; any engine is acceptable
        assert!(e < 2);
        r.done(e);
    }

    #[test]
    fn done_underflow_saturates_at_zero() {
        let r = Router::new(2);
        let s = sample(5);
        let e = r.pick(&s);
        r.done(e);
        r.done(e); // unmatched: must not wrap to u64::MAX
        r.done(1 - e);
        assert_eq!(r.loads(), vec![0, 0]);
        // routing still behaves after the double release
        let e2 = r.pick(&s);
        assert_eq!(r.loads().iter().sum::<u64>(), 1);
        r.done(e2);
    }
}
