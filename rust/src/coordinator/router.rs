//! Document-cache-affinity router (the vLLM-router shape): requests
//! whose document set hashes alike land on the same engine so its LRU
//! cache keeps serving them; load imbalance beyond a threshold falls
//! back to least-loaded.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::kvcache::store::doc_hash;
use crate::workload::Sample;

pub struct Router {
    in_flight: Vec<AtomicU64>,
    /// Allowed load gap before affinity is overridden.
    pub imbalance_limit: u64,
}

impl Router {
    pub fn new(n_engines: usize) -> Router {
        assert!(n_engines > 0);
        Router {
            in_flight: (0..n_engines).map(|_| AtomicU64::new(0)).collect(),
            imbalance_limit: 8,
        }
    }

    pub fn n_engines(&self) -> usize {
        self.in_flight.len()
    }

    /// Combined hash of the sample's document set (order-insensitive so
    /// permuted retrievals still hit the same engine cache).
    pub fn affinity_hash(sample: &Sample) -> u64 {
        sample
            .docs
            .iter()
            .map(|d| doc_hash(d))
            .fold(0u64, |acc, h| acc ^ h)
    }

    /// Pick an engine; callers must pair with [`Router::done`].
    pub fn pick(&self, sample: &Sample) -> usize {
        let n = self.in_flight.len();
        let preferred = (Self::affinity_hash(sample) % n as u64) as usize;
        let loads: Vec<u64> = self
            .in_flight
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect();
        let min = *loads.iter().min().unwrap();
        let chosen = if loads[preferred] > min + self.imbalance_limit {
            loads
                .iter()
                .enumerate()
                .min_by_key(|(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap()
        } else {
            preferred
        };
        self.in_flight[chosen].fetch_add(1, Ordering::Relaxed);
        chosen
    }

    pub fn done(&self, engine: usize) {
        self.in_flight[engine].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn loads(&self) -> Vec<u64> {
        self.in_flight
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(doc_seed: i32) -> Sample {
        Sample {
            docs: vec![vec![doc_seed, doc_seed + 1], vec![doc_seed + 2]],
            query: vec![2, 5, 16, 0, 3],
            answer: vec![],
            qtype: "t".into(),
        }
    }

    #[test]
    fn affinity_is_deterministic_and_order_insensitive() {
        let a = sample(10);
        let mut b = sample(10);
        b.docs.reverse();
        assert_eq!(Router::affinity_hash(&a), Router::affinity_hash(&b));
        assert_ne!(Router::affinity_hash(&a),
                   Router::affinity_hash(&sample(11)));
    }

    #[test]
    fn same_docs_same_engine() {
        let r = Router::new(4);
        let s = sample(42);
        let e1 = r.pick(&s);
        r.done(e1);
        let e2 = r.pick(&s);
        r.done(e2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn imbalance_falls_back_to_least_loaded() {
        let mut r = Router::new(2);
        r.imbalance_limit = 2;
        let s = sample(7);
        let preferred = r.pick(&s); // load 1 on preferred
        // pile more load onto the preferred engine
        for _ in 0..4 {
            r.in_flight[preferred].fetch_add(1, Ordering::Relaxed);
        }
        let other = r.pick(&s);
        assert_ne!(other, preferred);
        assert_eq!(r.loads().len(), 2);
    }

    #[test]
    fn loads_track_in_flight() {
        let r = Router::new(2);
        let s = sample(1);
        let e = r.pick(&s);
        assert_eq!(r.loads().iter().sum::<u64>(), 1);
        r.done(e);
        assert_eq!(r.loads().iter().sum::<u64>(), 0);
    }
}
