//! Engine: one thread owning a PJRT runtime + model + document cache,
//! serving requests from a channel (dynamic batching applied at the
//! queue). The PJRT client is not `Send`, so everything device-adjacent
//! lives here.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::kvcache::CacheStore;
use crate::metrics::Metrics;
use crate::model::Model;
use crate::policies::{all_policies, ContextPolicy};
use crate::runtime::Runtime;

use super::batcher::next_batch;
use super::request::{ServeRequest, ServeResponse};

enum Msg {
    Serve(ServeRequest, mpsc::Sender<ServeResponse>),
}

/// Cloneable handle for submitting work to one engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    pub index: usize,
}

impl EngineHandle {
    /// Fire a request; the response arrives on the returned receiver.
    pub fn submit(&self, req: ServeRequest)
                  -> Result<mpsc::Receiver<ServeResponse>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Serve(req, tx))
            .map_err(|_| anyhow::anyhow!("engine closed"))?;
        Ok(rx)
    }

    /// Convenience: submit and block for the response.
    pub fn serve(&self, req: ServeRequest) -> Result<ServeResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))
    }
}

pub struct Engine {
    handle: EngineHandle,
    join: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine thread: loads the runtime + model, compiles the
    /// serving entry points, then loops on the queue. `ready` resolves
    /// after warmup (Err when initialization failed).
    pub fn spawn(index: usize, artifacts: PathBuf, cfg: ServingConfig,
                 default_policy: String, metrics: Arc<Metrics>)
                 -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name(format!("engine-{index}"))
            .spawn(move || {
                engine_main(index, artifacts, cfg, default_policy, metrics,
                            rx, ready_tx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine init crashed"))??;
        Ok(Engine { handle: EngineHandle { tx, index }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // close the queue; the thread drains and exits
        let (dead_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.handle.tx, dead_tx);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(index: usize, artifacts: PathBuf, cfg: ServingConfig,
               default_policy: String, metrics: Arc<Metrics>,
               rx: mpsc::Receiver<Msg>,
               ready_tx: mpsc::Sender<Result<()>>) {
    let init = (|| -> Result<(Model, CacheStore)> {
        let rt = std::rc::Rc::new(Runtime::new(artifacts)?);
        let model = Model::load(rt, &cfg.profile)?;
        model.warmup()?;
        // budget: documents for ~64 concurrent doc-sets
        let budget = 64
            * model.cfg.n_docs
            * model.cfg.doc_len
            * model.cfg.kv_bytes_per_token()
            * 4;
        Ok((model, CacheStore::new(budget)))
    })();
    let (model, mut store) = match init {
        Ok(x) => {
            let _ = ready_tx.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let policies: HashMap<String, Box<dyn ContextPolicy>> = all_policies()
        .into_iter()
        .map(|p| (p.name(), p))
        .collect();
    crate::info!("engine-{index} ready (profile {}, {} params)",
                 model.name, model.n_params);

    while let Some(batch) =
        next_batch(&rx, cfg.max_batch, Duration::from_millis(2))
    {
        for msg in batch {
            let Msg::Serve(req, reply) = msg;
            metrics.requests.fetch_add(1, Ordering::Relaxed);
            let pname = if req.policy.is_empty() {
                default_policy.clone()
            } else {
                req.policy.clone()
            };
            let resp = match policies.get(&pname) {
                Some(policy) => {
                    match policy.run(&model, &mut store, &req.sample) {
                        Ok(out) => {
                            metrics.record_completion(
                                out.stats.ttft_ms,
                                out.stats.decode_ms,
                                out.answer.len(),
                                store.stats().current_bytes,
                            );
                            ServeResponse {
                                id: req.id,
                                answer: out.answer,
                                stats: out.stats,
                                error: None,
                            }
                        }
                        Err(e) => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            ServeResponse {
                                id: req.id,
                                answer: vec![],
                                stats: Default::default(),
                                error: Some(format!("{e:#}")),
                            }
                        }
                    }
                }
                None => {
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    ServeResponse {
                        id: req.id,
                        answer: vec![],
                        stats: Default::default(),
                        error: Some(format!("unknown policy `{pname}`")),
                    }
                }
            };
            let _ = reply.send(resp);
        }
    }
    crate::info!("engine-{index} shutting down");
}
