//! Engine: one thread owning a PJRT runtime + model + the engine-local
//! residency tier of the document cache, serving requests from a
//! channel. The PJRT client is not `Send`, so everything
//! device-adjacent lives here; the [`HostDocCache`] beneath the
//! residency tier is shared across all engines, so a document
//! prefilled by any engine is a host-tier hit for every other (see
//! [`crate::kvcache`]).
//!
//! # Continuous-batching scheduler
//!
//! The engine runs a persistent decode scheduler instead of the old
//! drain-to-empty batch loop. It owns a long-lived pool of [`Active`]
//! sessions and alternates two phases forever:
//!
//! 1. **Admission.** When the pool is empty the engine blocks on the
//!    queue ([`next_batch`]); while sessions are decoding it instead
//!    polls without blocking ([`poll_batch`]) between rounds, so an
//!    idle queue never stalls a token. Each admitted *wave* (at most
//!    `max_batch` requests, bounded by the `max_active` pool cap and
//!    coalesced within `batch_window_ms`) runs the front of the staged
//!    protocol ([`crate::policies::pipeline`]): every request is
//!    planned (pure, model-free), shared document prefills are
//!    deduplicated across the wave (the multi-context RAG hot path —
//!    the same retrieved document appearing in many concurrent
//!    requests is prefilled once and its cost split across sharers),
//!    then each newcomer assembles and attends and joins the pool.
//!    Per-request queue wait (submit → plan start) is recorded here,
//!    and the per-tier cache counters are flushed after every wave so
//!    they cannot go stale under continuous admission.
//!
//! 2. **One fused decode round.** Every active session emits at most
//!    one token ([`ServeSession::decode_step_begin`], round-robin in
//!    pool order — arrival order, newcomers at the back), then all
//!    requested forward passes run as a single amortized dispatch
//!    ([`Model::decode_batch`], counted in `Metrics::fused_rounds` /
//!    `fused_round_sessions`), and the outputs are folded back
//!    ([`ServeSession::decode_step_complete`]). Finished sessions are
//!    retired at the end of the round — token events of a round are
//!    always sent before any of its `Done` events.
//!
//! Because admission happens *between rounds*, a newly arrived request
//! reaches its first token after at most one round plus its own
//! prefill/assemble/attend — it no longer waits for the oldest
//! request's full decode, which is the TTFT win continuous batching
//! exists for.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServingConfig;
use crate::kvcache::{
    EngineDocCache, HostDocCache, ResidencyHandle, TierHit,
};
use crate::metrics::Metrics;
use crate::model::{DecodeReq, Model};
use crate::policies::pipeline::{
    dedup_doc_plans, FnSink, FusedStep, ServeSession,
};
use crate::policies::{all_policies, ContextPolicy};
use crate::runtime::Runtime;

use super::batcher::{next_batch, poll_batch};
use super::request::{recv_done, ServeEvent, ServeRequest, ServeResponse};

enum Msg {
    /// A request, its reply channel, and its submission instant (the
    /// queue-wait clock starts at submit).
    Serve(ServeRequest, mpsc::Sender<ServeEvent>, Instant),
}

/// Cloneable handle for submitting work to one engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    pub index: usize,
}

impl EngineHandle {
    /// Fire a request; events (streamed tokens, then the terminal
    /// response) arrive on the returned receiver.
    pub fn submit(&self, req: ServeRequest)
                  -> Result<mpsc::Receiver<ServeEvent>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Serve(req, tx, Instant::now()))
            .map_err(|_| anyhow::anyhow!("engine closed"))?;
        Ok(rx)
    }

    /// Convenience: submit and block for the terminal response.
    pub fn serve(&self, req: ServeRequest) -> Result<ServeResponse> {
        let rx = self.submit(req)?;
        recv_done(&rx)
    }
}

pub struct Engine {
    /// `Some` while the engine runs; taken on drop to close the queue.
    tx: Option<mpsc::Sender<Msg>>,
    index: usize,
    join: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine thread: loads the runtime + model, compiles the
    /// serving entry points, then runs the persistent scheduler on the
    /// queue. The engine's residency tier is constructed over the
    /// shared `host` tier; `residency` (when routed) advertises
    /// resident hashes for cache-aware placement. `ready` resolves
    /// after warmup (Err when initialization failed).
    pub fn spawn(index: usize, artifacts: PathBuf, cfg: ServingConfig,
                 default_policy: String, metrics: Arc<Metrics>,
                 host: Arc<HostDocCache>,
                 residency: Option<ResidencyHandle>) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name(format!("engine-{index}"))
            .spawn(move || {
                engine_main(index, artifacts, cfg, default_policy, metrics,
                            host, residency, rx, ready_tx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine init crashed"))??;
        Ok(Engine { tx: Some(tx), index, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        EngineHandle {
            tx: self.tx.clone().expect("engine running"),
            index: self.index,
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // close our end of the queue; the thread drains and exits once
        // every outstanding `EngineHandle` clone is gone too
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// One pooled session: the staged state machine plus what is needed to
/// stream its events after the originating request has been consumed.
struct Active<'p> {
    id: u64,
    stream: bool,
    reply: mpsc::Sender<ServeEvent>,
    session: ServeSession<'p, dyn ContextPolicy>,
}

#[allow(clippy::too_many_arguments)]
fn engine_main(index: usize, artifacts: PathBuf, cfg: ServingConfig,
               default_policy: String, metrics: Arc<Metrics>,
               host: Arc<HostDocCache>,
               residency: Option<ResidencyHandle>,
               rx: mpsc::Receiver<Msg>,
               ready_tx: mpsc::Sender<Result<()>>) {
    let init = (|| -> Result<(Model, EngineDocCache)> {
        let rt = std::rc::Rc::new(Runtime::new(artifacts)?);
        let model = Model::load(rt, &cfg.profile)?;
        model.warmup()?;
        // residency budget: documents for ~64 concurrent doc-sets
        let budget = 64
            * model.cfg.n_docs
            * model.cfg.doc_len
            * model.cfg.kv_bytes_per_token()
            * 4;
        // an auto-sized host tier is bounded too: hold ~4 engines'
        // worth of residency (explicitly configured budgets win)
        host.ensure_min_budget(budget.saturating_mul(4));
        Ok((model,
            EngineDocCache::new(host, budget).with_residency(residency)))
    })();
    let (model, mut store) = match init {
        Ok(x) => {
            let _ = ready_tx.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let policies: HashMap<String, Box<dyn ContextPolicy>> = all_policies()
        .into_iter()
        .map(|p| (p.name(), p))
        .collect();
    crate::info!("engine-{index} ready (profile {}, {} params)",
                 model.name, model.n_params);

    // --- the persistent scheduler -------------------------------------
    let window = Duration::from_millis(cfg.batch_window_ms);
    let max_active = cfg.max_active.max(1);
    let wave_cap = cfg.max_batch.max(1);
    let mut active: Vec<Active> = Vec::new();
    let mut open = true;
    loop {
        if active.is_empty() {
            if !open {
                break;
            }
            // idle: block for work (or exit once the queue closes)
            match next_batch(&rx, wave_cap.min(max_active), window) {
                Some(wave) => admit_wave(&model, &mut store, &policies,
                                         &default_policy, &metrics, wave,
                                         &mut active),
                None => open = false,
            }
        } else if open {
            // mid-round admission: a non-blocking poll between decode
            // rounds, capped by the pool's free slots
            let free = max_active.saturating_sub(active.len());
            if free > 0 {
                let (wave, still_open) =
                    poll_batch(&rx, free.min(wave_cap), window);
                open = still_open;
                if !wave.is_empty() {
                    admit_wave(&model, &mut store, &policies,
                               &default_policy, &metrics, wave,
                               &mut active);
                }
            }
        }
        if !active.is_empty() {
            decode_round(&model, &store, &metrics, &mut active);
        }
    }
    crate::info!("engine-{index} shutting down");
}

fn error_response(id: u64, msg: String) -> ServeResponse {
    ServeResponse {
        id,
        answer: vec![],
        stats: Default::default(),
        error: Some(msg),
    }
}

/// Admit one wave of queued requests into the active pool: plan every
/// request, dedup shared document prefills across the wave, then run
/// each survivor's prefill/assemble/attend. Requests that fail any
/// stage are answered with an error immediately; survivors join the
/// pool (at the back — round-robin order is arrival order).
fn admit_wave<'p>(model: &Model, store: &mut EngineDocCache,
                  policies: &'p HashMap<String, Box<dyn ContextPolicy>>,
                  default_policy: &str, metrics: &Metrics,
                  wave: Vec<Msg>, active: &mut Vec<Active<'p>>) {
    // --- stage 1: plan every request (pure, model-free) ---------------
    let mut items: Vec<(u64, bool, mpsc::Sender<ServeEvent>)> =
        Vec::with_capacity(wave.len());
    let mut sessions: Vec<Option<ServeSession<'p, dyn ContextPolicy>>> =
        Vec::with_capacity(wave.len());
    for msg in wave {
        let Msg::Serve(req, reply, submitted) = msg;
        let ServeRequest { id, sample, policy, stream } = req;
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        let queue_wait_ms = submitted.elapsed().as_secs_f64() * 1e3;
        metrics.queue_wait.observe_ms(queue_wait_ms);
        let pname = if policy.is_empty() {
            default_policy
        } else {
            policy.as_str()
        };
        match policies.get(pname) {
            Some(p) => {
                let mut s =
                    ServeSession::new(p.as_ref(), &model.cfg, sample);
                s.set_queue_wait(queue_wait_ms);
                sessions.push(Some(s));
            }
            None => {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(ServeEvent::Done(error_response(
                    id, format!("unknown policy `{pname}`"))));
                sessions.push(None);
            }
        }
        items.push((id, stream, reply));
    }

    // --- stage 2: cross-request doc-prefill dedup ----------------------
    // prefill each document needed by the wave exactly once; split the
    // cost across the requests sharing it. The whole wave's planned
    // hashes are pinned for the duration so no tier eviction can race
    // the per-session stages below.
    let shared = {
        let plans: Vec<Option<&crate::policies::ServePlan>> = sessions
            .iter()
            .map(|s| s.as_ref().map(|s| s.plan()))
            .collect();
        dedup_doc_plans(&plans)
    };
    let _wave_pins = {
        let hashes: Vec<u64> = shared.iter().map(|sd| sd.hash).collect();
        store.pin_planned(&hashes)
    };
    for sd in &shared {
        // sharers may have died earlier in this stage (a previous doc's
        // prefill failed); don't prefill for nobody, and split the cost
        // over the requests actually served
        let live: Vec<usize> = sd
            .sharers
            .iter()
            .copied()
            .filter(|&si| sessions[si].is_some())
            .collect();
        if live.is_empty() {
            continue;
        }
        // locate the document's tokens through the first live sharer
        // (plan hash order mirrors its sample's doc order)
        let (owner, dj) = {
            let s = sessions[live[0]].as_ref().unwrap();
            let dj = s
                .plan()
                .doc_hashes
                .iter()
                .position(|&h| h == sd.hash)
                .expect("live sharer plans the doc");
            (live[0], dj)
        };
        let t = Instant::now();
        let hit = {
            let tokens = &sessions[owner].as_ref().unwrap().sample().docs[dj];
            store.get_or_prefill(model, tokens)
        };
        match hit {
            // already resident: free
            Ok((_, TierHit::Resident)) => continue,
            // host-tier hit — but the lookup may have blocked on
            // another engine's in-flight prefill lease; attribute that
            // wait to the sharers' doc_prefill time (cache still warm:
            // no local prefill ran)
            Ok((_, TierHit::Host)) => {
                let share =
                    t.elapsed().as_secs_f64() * 1e3 / live.len() as f64;
                for &si in &live {
                    if let Some(s) = sessions[si].as_mut() {
                        s.credit_shared_prefill(share, false);
                    }
                }
                continue;
            }
            Ok((_, TierHit::Prefilled)) => {}
            Err(e) => {
                // fail every live sharer now rather than re-running the
                // (expensive, failing) prefill once per request later
                for &si in &live {
                    sessions[si] = None;
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let (id, _, reply) = &items[si];
                    let _ = reply.send(ServeEvent::Done(error_response(
                        *id, format!("doc prefill failed: {e:#}"))));
                }
                continue;
            }
        }
        metrics.doc_prefills.fetch_add(1, Ordering::Relaxed);
        let share = t.elapsed().as_secs_f64() * 1e3 / live.len() as f64;
        for &si in &live {
            if let Some(s) = sessions[si].as_mut() {
                s.credit_shared_prefill(share, true);
            }
        }
    }

    // --- stage 3: per-request prefill (cache hits) + assemble + attend
    for i in 0..sessions.len() {
        if sessions[i].is_none() {
            continue;
        }
        let staged = (|| -> Result<()> {
            let s = sessions[i].as_mut().unwrap();
            s.prefill_docs(model, store)?;
            s.assemble(model)?;
            s.attend(model)
        })();
        if let Err(e) = staged {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let (id, _, reply) = &items[i];
            let _ = reply.send(ServeEvent::Done(error_response(
                *id, format!("{e:#}"))));
            sessions[i] = None;
        }
    }

    // flush per-tier cache counters after every admission wave — decode
    // never touches the doc cache, and under continuous admission there
    // is no "end of batch" to flush at, so this is the only point where
    // the counters stay in lockstep with responses
    metrics.record_cache_tiers(&store.host_stats(),
                               &store.take_stats_delta());

    // --- survivors join the decode pool --------------------------------
    for ((id, stream, reply), s) in items.into_iter().zip(sessions) {
        if let Some(session) = s {
            metrics.active_sessions.fetch_add(1, Ordering::Relaxed);
            active.push(Active { id, stream, reply, session });
        }
    }
}

/// One fused decode round over the pool: every session emits at most
/// one token (round-robin in pool order), all requested forward passes
/// run as one [`Model::decode_batch`] dispatch, and finished or failed
/// sessions are retired — after the round's token emissions, so a
/// round's `Done` events never precede its tokens.
fn decode_round(model: &Model, store: &EngineDocCache, metrics: &Metrics,
                active: &mut Vec<Active<'_>>) {
    // --- emit: at most one token per session ---------------------------
    let mut pending: Vec<(usize, FusedStep)> = Vec::new();
    let mut finished: Vec<usize> = Vec::new();
    let mut dead: Vec<(usize, String)> = Vec::new();
    for i in 0..active.len() {
        let Active { id, stream, reply, session } = &mut active[i];
        let (id, stream) = (*id, *stream);
        let index = session.answer().len();
        let mut sink = FnSink(|token: i32| {
            if stream {
                let _ = reply.send(ServeEvent::Token { id, index, token });
            }
        });
        match session.decode_step_begin(&mut sink) {
            Ok((_, Some(step))) => pending.push((i, step)),
            Ok((_, None)) => finished.push(i),
            Err(e) => dead.push((i, format!("{e:#}"))),
        }
    }

    // --- one fused dispatch for every session that wants logits --------
    let mut reqs: Vec<DecodeReq> = Vec::with_capacity(pending.len());
    let mut dispatch: Vec<(usize, FusedStep)> =
        Vec::with_capacity(pending.len());
    for &(i, step) in &pending {
        match active[i].session.decode_inputs() {
            Ok((buffer, kv, kv_valid)) => {
                reqs.push(DecodeReq {
                    buffer,
                    token: step.token,
                    pos: step.pos,
                    slot: step.slot as i32,
                    kv,
                    kv_valid,
                });
                dispatch.push((i, step));
            }
            Err(e) => dead.push((i, format!("{e:#}"))),
        }
    }
    if !dispatch.is_empty() {
        metrics.fused_rounds.fetch_add(1, Ordering::Relaxed);
        metrics
            .fused_round_sessions
            .fetch_add(dispatch.len() as u64, Ordering::Relaxed);
        let t = Instant::now();
        let outs = model.decode_batch(&reqs);
        drop(reqs);
        let share =
            t.elapsed().as_secs_f64() * 1e3 / dispatch.len() as f64;
        // per-request outcomes: a failing session is retired alone and
        // never poisons the rest of the round
        for (&(i, step), out) in dispatch.iter().zip(outs) {
            let folded = out.and_then(|o| {
                active[i].session.decode_step_complete(step, o, share)
            });
            if let Err(e) = folded {
                dead.push((i, format!("{e:#}")));
            }
        }
    }

    // --- retire finished/failed sessions (descending index keeps the
    // remaining pool's round-robin order stable) ------------------------
    let mut retire: Vec<(usize, Option<String>)> = finished
        .into_iter()
        .map(|i| (i, None))
        .chain(dead.into_iter().map(|(i, e)| (i, Some(e))))
        .collect();
    retire.sort_by_key(|r| std::cmp::Reverse(r.0));
    for (i, err) in retire {
        let a = active.remove(i);
        metrics.active_sessions.fetch_sub(1, Ordering::Relaxed);
        match err {
            None => {
                let out = a.session.finish();
                metrics.record_completion(
                    out.stats.ttft_ms,
                    out.stats.decode_ms,
                    out.answer.len(),
                    store.stats().current_bytes,
                );
                metrics.record_stage_times(out.stats.plan_ms,
                                           out.stats.doc_prefill_ms);
                let _ = a.reply.send(ServeEvent::Done(ServeResponse {
                    id: a.id,
                    answer: out.answer,
                    stats: out.stats,
                    error: None,
                }));
            }
            Some(msg) => {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = a.reply.send(ServeEvent::Done(error_response(
                    a.id, msg)));
            }
        }
    }
}
