//! Engine: one thread owning a PJRT runtime + model + the engine-local
//! residency tier of the document cache, serving requests from a
//! channel (dynamic batching applied at the queue). The PJRT client is
//! not `Send`, so everything device-adjacent lives here; the
//! [`HostDocCache`] beneath the residency tier is shared across all
//! engines, so a document prefilled by any engine is a host-tier hit
//! for every other (see [`crate::kvcache`]).
//!
//! The batch loop exploits the staged policy protocol
//! ([`crate::policies::pipeline`]): every request in the batch is
//! planned up front (pure, model-free), shared document prefills are
//! deduplicated across the batch (the multi-context RAG hot path —
//! the same retrieved document appearing in many concurrent requests is
//! prefilled once and its cost split across sharers), then the
//! per-request assemble/attend/decode stages are interleaved
//! round-robin so streaming requests emit tokens fairly instead of
//! serializing whole requests.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServingConfig;
use crate::kvcache::{
    EngineDocCache, HostDocCache, ResidencyHandle, TierHit,
};
use crate::metrics::Metrics;
use crate::model::Model;
use crate::policies::pipeline::{dedup_doc_plans, FnSink, ServeSession};
use crate::policies::{all_policies, ContextPolicy, ServePlan};
use crate::runtime::Runtime;

use super::batcher::next_batch;
use super::request::{recv_done, ServeEvent, ServeRequest, ServeResponse};

enum Msg {
    Serve(ServeRequest, mpsc::Sender<ServeEvent>),
}

/// Cloneable handle for submitting work to one engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    pub index: usize,
}

impl EngineHandle {
    /// Fire a request; events (streamed tokens, then the terminal
    /// response) arrive on the returned receiver.
    pub fn submit(&self, req: ServeRequest)
                  -> Result<mpsc::Receiver<ServeEvent>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Serve(req, tx))
            .map_err(|_| anyhow::anyhow!("engine closed"))?;
        Ok(rx)
    }

    /// Convenience: submit and block for the terminal response.
    pub fn serve(&self, req: ServeRequest) -> Result<ServeResponse> {
        let rx = self.submit(req)?;
        recv_done(&rx)
    }
}

pub struct Engine {
    /// `Some` while the engine runs; taken on drop to close the queue.
    tx: Option<mpsc::Sender<Msg>>,
    index: usize,
    join: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine thread: loads the runtime + model, compiles the
    /// serving entry points, then loops on the queue. The engine's
    /// residency tier is constructed over the shared `host` tier;
    /// `residency` (when routed) advertises resident hashes for
    /// cache-aware placement. `ready` resolves after warmup (Err when
    /// initialization failed).
    pub fn spawn(index: usize, artifacts: PathBuf, cfg: ServingConfig,
                 default_policy: String, metrics: Arc<Metrics>,
                 host: Arc<HostDocCache>,
                 residency: Option<ResidencyHandle>) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name(format!("engine-{index}"))
            .spawn(move || {
                engine_main(index, artifacts, cfg, default_policy, metrics,
                            host, residency, rx, ready_tx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine init crashed"))??;
        Ok(Engine { tx: Some(tx), index, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        EngineHandle {
            tx: self.tx.clone().expect("engine running"),
            index: self.index,
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // close our end of the queue; the thread drains and exits once
        // every outstanding `EngineHandle` clone is gone too
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn engine_main(index: usize, artifacts: PathBuf, cfg: ServingConfig,
               default_policy: String, metrics: Arc<Metrics>,
               host: Arc<HostDocCache>,
               residency: Option<ResidencyHandle>,
               rx: mpsc::Receiver<Msg>,
               ready_tx: mpsc::Sender<Result<()>>) {
    let init = (|| -> Result<(Model, EngineDocCache)> {
        let rt = std::rc::Rc::new(Runtime::new(artifacts)?);
        let model = Model::load(rt, &cfg.profile)?;
        model.warmup()?;
        // residency budget: documents for ~64 concurrent doc-sets
        let budget = 64
            * model.cfg.n_docs
            * model.cfg.doc_len
            * model.cfg.kv_bytes_per_token()
            * 4;
        // an auto-sized host tier is bounded too: hold ~4 engines'
        // worth of residency (explicitly configured budgets win)
        host.ensure_min_budget(budget.saturating_mul(4));
        Ok((model,
            EngineDocCache::new(host, budget).with_residency(residency)))
    })();
    let (model, mut store) = match init {
        Ok(x) => {
            let _ = ready_tx.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let policies: HashMap<String, Box<dyn ContextPolicy>> = all_policies()
        .into_iter()
        .map(|p| (p.name(), p))
        .collect();
    crate::info!("engine-{index} ready (profile {}, {} params)",
                 model.name, model.n_params);

    while let Some(batch) =
        next_batch(&rx, cfg.max_batch, Duration::from_millis(2))
    {
        serve_batch(&model, &mut store, &policies, &default_policy,
                    &metrics, batch);
    }
    crate::info!("engine-{index} shutting down");
}

fn error_response(id: u64, msg: String) -> ServeResponse {
    ServeResponse {
        id,
        answer: vec![],
        stats: Default::default(),
        error: Some(msg),
    }
}

/// Serve one gathered batch through the staged protocol.
fn serve_batch(model: &Model, store: &mut EngineDocCache,
               policies: &HashMap<String, Box<dyn ContextPolicy>>,
               default_policy: &str, metrics: &Metrics,
               batch: Vec<Msg>) {
    let items: Vec<(ServeRequest, mpsc::Sender<ServeEvent>)> = batch
        .into_iter()
        .map(|m| match m {
            Msg::Serve(req, reply) => (req, reply),
        })
        .collect();
    metrics.requests.fetch_add(items.len() as u64, Ordering::Relaxed);

    // --- stage 1: plan every request (pure, model-free) ---------------
    let mut sessions: Vec<Option<ServeSession<dyn ContextPolicy>>> =
        Vec::with_capacity(items.len());
    for (req, reply) in &items {
        let pname = if req.policy.is_empty() {
            default_policy
        } else {
            req.policy.as_str()
        };
        match policies.get(pname) {
            Some(p) => sessions.push(Some(ServeSession::new(
                p.as_ref(), &model.cfg, &req.sample))),
            None => {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(ServeEvent::Done(error_response(
                    req.id, format!("unknown policy `{pname}`"))));
                sessions.push(None);
            }
        }
    }

    // --- stage 2: cross-request doc-prefill dedup ----------------------
    // prefill each document needed by the batch exactly once; split the
    // cost across the requests sharing it. The whole batch's planned
    // hashes are pinned for the duration so no tier eviction can race
    // the per-session stages below.
    let shared = {
        let plans: Vec<Option<&ServePlan>> = sessions
            .iter()
            .map(|s| s.as_ref().map(|s| s.plan()))
            .collect();
        dedup_doc_plans(&plans)
    };
    let _batch_pins = {
        let hashes: Vec<u64> = shared.iter().map(|sd| sd.hash).collect();
        store.pin_planned(&hashes)
    };
    for sd in &shared {
        // sharers may have died earlier in this stage (a previous doc's
        // prefill failed); don't prefill for nobody, and split the cost
        // over the requests actually served
        let live: Vec<usize> = sd
            .sharers
            .iter()
            .copied()
            .filter(|&si| sessions[si].is_some())
            .collect();
        if live.is_empty() {
            continue;
        }
        let tokens = &items[sd.req].0.sample.docs[sd.doc];
        let t = Instant::now();
        match store.get_or_prefill(model, tokens) {
            // already resident: free
            Ok((_, TierHit::Resident)) => continue,
            // host-tier hit — but the lookup may have blocked on
            // another engine's in-flight prefill lease; attribute that
            // wait to the sharers' doc_prefill time (cache still warm:
            // no local prefill ran)
            Ok((_, TierHit::Host)) => {
                let share =
                    t.elapsed().as_secs_f64() * 1e3 / live.len() as f64;
                for &si in &live {
                    if let Some(s) = sessions[si].as_mut() {
                        s.credit_shared_prefill(share, false);
                    }
                }
                continue;
            }
            Ok((_, TierHit::Prefilled)) => {}
            Err(e) => {
                // fail every live sharer now rather than re-running the
                // (expensive, failing) prefill once per request later
                for &si in &live {
                    sessions[si] = None;
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let (req, reply) = &items[si];
                    let _ = reply.send(ServeEvent::Done(error_response(
                        req.id, format!("doc prefill failed: {e:#}"))));
                }
                continue;
            }
        }
        metrics.doc_prefills.fetch_add(1, Ordering::Relaxed);
        let share = t.elapsed().as_secs_f64() * 1e3 / live.len() as f64;
        for &si in &live {
            if let Some(s) = sessions[si].as_mut() {
                s.credit_shared_prefill(share, true);
            }
        }
    }

    // --- stage 3: per-request prefill (cache hits) + assemble + attend
    for i in 0..sessions.len() {
        if sessions[i].is_none() {
            continue;
        }
        let staged = (|| -> Result<()> {
            let s = sessions[i].as_mut().unwrap();
            s.prefill_docs(model, store)?;
            s.assemble(model)?;
            s.attend(model)
        })();
        if let Err(e) = staged {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let (req, reply) = &items[i];
            let _ = reply.send(ServeEvent::Done(error_response(
                req.id, format!("{e:#}"))));
            sessions[i] = None;
        }
    }

    // flush per-tier cache counters now — decode below never touches
    // the doc cache, and responses must not outrun the stats they
    // describe (metrics report, server wire, bench JSON)
    metrics.record_cache_tiers(&store.host_stats(),
                               &store.take_stats_delta());

    // --- stage 4: interleaved decode, one token per session per round
    loop {
        let mut progressed = false;
        for i in 0..sessions.len() {
            if sessions[i].is_none() {
                continue;
            }
            let (req, reply) = &items[i];
            let step = {
                let s = sessions[i].as_mut().unwrap();
                let index = s.answer().len();
                let mut sink = FnSink(|token: i32| {
                    if req.stream {
                        let _ = reply.send(ServeEvent::Token {
                            id: req.id,
                            index,
                            token,
                        });
                    }
                });
                s.decode_step(model, &mut sink)
            };
            match step {
                Ok(Some(_)) => progressed = true,
                Ok(None) => {
                    let out = sessions[i].take().unwrap().finish();
                    metrics.record_completion(
                        out.stats.ttft_ms,
                        out.stats.decode_ms,
                        out.answer.len(),
                        store.stats().current_bytes,
                    );
                    metrics.record_stage_times(out.stats.plan_ms,
                                               out.stats.doc_prefill_ms);
                    let _ = reply.send(ServeEvent::Done(ServeResponse {
                        id: req.id,
                        answer: out.answer,
                        stats: out.stats,
                        error: None,
                    }));
                }
                Err(e) => {
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(ServeEvent::Done(error_response(
                        req.id, format!("{e:#}"))));
                    sessions[i] = None;
                }
            }
        }
        if !progressed {
            break;
        }
    }
}
