//! Engine: one serving instance made of **two** threads — a decode
//! thread owning the decode-side PJRT runtime, and an admission helper
//! owning a second runtime plus the engine-local residency tier of the
//! document cache. The PJRT client is not `Send`, so each thread loads
//! its own `Runtime`/`Model` over the same artifacts; the
//! [`HostDocCache`] beneath the admission thread's residency tier is
//! shared across all engines, so a document prefilled by any engine is
//! a host-tier hit for every other (see [`crate::kvcache`]).
//!
//! # Overlapped continuous-batching scheduler
//!
//! The old scheduler ran admission work (plan → doc-prefill dedup →
//! assemble → attend) *between* decode rounds on the engine thread, so
//! every newcomer's prefill stalled every active session's next token.
//! Now the two stages run concurrently:
//!
//! 1. **Admission helper thread.** Blocks on the request queue
//!    ([`next_batch`]) after reserving decode-pool room on a counting
//!    [`Gate`] (slots freed as sessions retire; the pool cap is
//!    `max_active`). Each gathered *wave* (at most `max_batch`
//!    requests, coalesced within `batch_window_ms`) runs the front of
//!    the staged protocol ([`crate::policies::pipeline`]): every
//!    request is planned (pure, model-free), shared document prefills
//!    are deduplicated across the wave (the multi-context RAG hot
//!    path), the wave's planned doc hashes are prefetched from the
//!    persistent disk cache tier when one is attached
//!    ([`EngineDocCache::prefetch_from_disk`] — disk latency overlaps
//!    in-flight decode the same way assemble does), then each newcomer
//!    assembles and attends **on the helper's
//!    own model** — request B's assemble overlaps request A's decode
//!    rounds (measured by `Metrics::assemble_overlap_ms`). Completed
//!    sessions are handed to the decode thread over a channel; requests
//!    that fail any stage are answered immediately and their pool slot
//!    released. Per-request queue wait (submit → plan start) is
//!    recorded here, and the per-tier cache counters plus the KV
//!    block-pool snapshot are flushed after every wave so they cannot
//!    go stale under continuous admission.
//!
//! 2. **Decode thread.** Integrates admitted sessions between rounds
//!    (blocking only when its pool is empty), then runs one fused
//!    decode round: every active session emits at most one token
//!    ([`ServeSession::decode_step_begin`], round-robin in pool order —
//!    arrival order, newcomers at the back), all requested forward
//!    passes go through **one [`Model::decode_batch`] call** — which
//!    packs same-buffer sessions into the lane-padded
//!    `decode_{sparse,full}_batched` artifacts, a single XLA execution
//!    per lane chunk (counted by `Metrics::record_decode_round`:
//!    `fused_rounds`, `round_executions`, `batched_rounds`, lane
//!    occupancy) — and the outputs are folded back
//!    ([`ServeSession::decode_step_complete`]). Finished sessions are
//!    retired at the end of the round — token events of a round are
//!    always sent before any of its `Done` events — and their pool
//!    slots released back to the admission gate.
//!
//! Because admission runs beside decode, a newly arrived request
//! reaches its first token after its own prefill/assemble/attend plus
//! at most one round's integration wait — it no longer waits for the
//! oldest request's full decode, and the pool no longer stops decoding
//! while newcomers prefill.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServingConfig;
use crate::exec::Gate;
use crate::faultinject::FaultSite;
use crate::kvcache::{
    EngineDocCache, HostDocCache, ResidencyHandle, TierHit,
};
use crate::metrics::Metrics;
use crate::model::{DecodeReq, Model};
use crate::policies::pipeline::{
    dedup_doc_plans, FnSink, FusedStep, ServeSession, SharedDoc,
};
use crate::policies::{all_policies, ContextPolicy};
use crate::runtime::Runtime;

use super::batcher::next_batch;
use super::request::{recv_done, ServeEvent, ServeRequest, ServeResponse};

enum Msg {
    /// A request, its reply channel, and its submission instant (the
    /// queue-wait clock starts at submit).
    Serve(ServeRequest, mpsc::Sender<ServeEvent>, Instant),
}

/// Cloneable handle for submitting work to one engine.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    pub index: usize,
    alive: Arc<AtomicBool>,
}

impl EngineHandle {
    /// False once the engine's decode thread has exited — crash,
    /// panic unwind, or an injected `engine_kill` fault. The server
    /// checks this before placing a request so a known-dead engine is
    /// skipped without paying a failed submit.
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// Fire a request; events (streamed tokens, then the terminal
    /// response) arrive on the returned receiver.
    pub fn submit(&self, req: ServeRequest)
                  -> Result<mpsc::Receiver<ServeEvent>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Serve(req, tx, Instant::now()))
            .map_err(|_| anyhow::anyhow!("engine closed"))?;
        Ok(rx)
    }

    /// Convenience: submit and block for the terminal response.
    pub fn serve(&self, req: ServeRequest) -> Result<ServeResponse> {
        let rx = self.submit(req)?;
        recv_done(&rx)
    }
}

pub struct Engine {
    /// `Some` while the engine runs; taken on drop to close the queue.
    tx: Option<mpsc::Sender<Msg>>,
    index: usize,
    alive: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine: the decode thread loads its runtime + model
    /// and compiles the decode entry points (including the lane-padded
    /// batched variants when the artifact set provides them), then
    /// spawns the admission helper thread, which loads a second
    /// runtime/model for the admission-side entry points and owns the
    /// engine's residency tier over the shared `host` tier; `residency`
    /// (when routed) advertises resident hashes for cache-aware
    /// placement. `ready` resolves after both threads warmed up (Err
    /// when either initialization failed).
    ///
    /// The two-thread split costs a second runtime + weight copy per
    /// engine and pays off when admission can overlap decode — i.e.
    /// `max_active >= 2`. With `--max-active 1` the helper strictly
    /// serializes behind session retirement; that degraded config keeps
    /// the double footprint rather than a second scheduler
    /// implementation.
    pub fn spawn(index: usize, artifacts: PathBuf, cfg: ServingConfig,
                 default_policy: String, metrics: Arc<Metrics>,
                 host: Arc<HostDocCache>,
                 residency: Option<ResidencyHandle>) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        // liveness flag shared with every handle: flipped false when
        // the decode thread exits for any reason (see `AliveGuard`)
        let alive = Arc::new(AtomicBool::new(true));
        let decode_alive = Arc::clone(&alive);
        let join = thread::Builder::new()
            .name(format!("engine-{index}"))
            .spawn(move || {
                engine_main(index, artifacts, cfg, default_policy, metrics,
                            host, residency, rx, ready_tx, decode_alive);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine init crashed"))??;
        Ok(Engine { tx: Some(tx), index, alive, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        // `tx` is only taken in `Drop`, so it is present for the whole
        // borrowable life of the engine; should that ever change, a
        // handle built on a closed channel degrades to structured
        // `submit` errors rather than a panic here.
        let tx = match &self.tx {
            Some(tx) => tx.clone(),
            None => mpsc::channel().0,
        };
        EngineHandle {
            tx,
            index: self.index,
            alive: Arc::clone(&self.alive),
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // close our end of the queue; the admission thread drains and
        // exits once every outstanding `EngineHandle` clone is gone,
        // then the decode thread drains its pool and joins it
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// One pooled session: the staged state machine plus what is needed to
/// stream its events after the originating request has been consumed.
/// Crosses from the admission thread to the decode thread, hence the
/// `'static` policy borrow (the policy table is leaked per engine).
struct Active {
    id: u64,
    stream: bool,
    reply: mpsc::Sender<ServeEvent>,
    /// `submit + --request-timeout-ms` when a deadline is configured;
    /// the decode loop retires the session with a structured timeout
    /// error once it passes.
    deadline: Option<Instant>,
    session: ServeSession<'static, dyn ContextPolicy>,
}

/// One admission wave's survivors, handed from the admission helper to
/// the decode thread between rounds.
struct AdmittedWave {
    ready: Vec<Active>,
    /// Residency-tier footprint after the wave (the decode thread
    /// reports it with completions; it no longer owns the store).
    resident_bytes: usize,
}

#[allow(clippy::too_many_arguments)]
fn engine_main(index: usize, artifacts: PathBuf, cfg: ServingConfig,
               default_policy: String, metrics: Arc<Metrics>,
               host: Arc<HostDocCache>,
               residency: Option<ResidencyHandle>,
               rx: mpsc::Receiver<Msg>,
               ready_tx: mpsc::Sender<Result<()>>,
               decode_alive: Arc<AtomicBool>) {
    // flips `decode_alive` when this thread exits — including a panic
    // unwind — so the admission helper's slot wait can never outlive
    // the decode thread that would have freed the slots, and the
    // server's `is_alive` pre-check sees the death promptly
    struct AliveGuard(Arc<AtomicBool>);
    impl Drop for AliveGuard {
        fn drop(&mut self) {
            self.0.store(false, Ordering::Relaxed);
        }
    }
    let _alive = AliveGuard(Arc::clone(&decode_alive));
    // --- decode-side init: runtime + model, decode entries only -------
    let init = (|| -> Result<Model> {
        let rt = std::rc::Rc::new(Runtime::new(artifacts.clone())?);
        let model = Model::load(rt, &cfg.profile)?;
        model.warmup_entries(&[
            "decode_sparse",
            "decode_full",
            "decode_sparse_batched",
            "decode_full_batched",
        ])?;
        Ok(model)
    })();
    let model = match init {
        Ok(m) => m,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };

    // --- admission helper: own runtime/model + the residency tier -----
    let gate = Arc::new(Gate::new(cfg.max_active.max(1)));
    let decoding = Arc::new(AtomicUsize::new(0));
    // the decode loop keeps its own handle on the fault plan (cfg
    // itself moves into the admission thread)
    let faults = cfg.fault_plan.clone();
    let (adm_tx, adm_rx) = mpsc::channel::<AdmittedWave>();
    let (adm_ready_tx, adm_ready_rx) = mpsc::channel::<Result<()>>();
    let admission = {
        let metrics = Arc::clone(&metrics);
        let (gate, decoding) = (Arc::clone(&gate), Arc::clone(&decoding));
        let decode_alive = Arc::clone(&decode_alive);
        thread::Builder::new()
            .name(format!("admit-{index}"))
            .spawn(move || {
                admission_main(index, artifacts, cfg, default_policy,
                               metrics, host, residency, rx, adm_tx,
                               gate, decoding, decode_alive,
                               adm_ready_tx);
            })
    };
    let admission = match admission {
        Ok(j) => j,
        Err(e) => {
            let _ = ready_tx.send(Err(e.into()));
            return;
        }
    };
    match adm_ready_rx.recv() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            let _ = ready_tx.send(Err(e));
            let _ = admission.join();
            return;
        }
        Err(_) => {
            let _ = ready_tx
                .send(Err(anyhow::anyhow!("admission init crashed")));
            let _ = admission.join();
            return;
        }
    }
    let _ = ready_tx.send(Ok(()));
    crate::info!("engine-{index} ready (profile {}, {} params)",
                 model.name, model.n_params);

    // --- the decode scheduler -----------------------------------------
    let mut active: Vec<Active> = Vec::new();
    let mut cache_bytes = 0usize;
    loop {
        // injected decode-thread death (chaos testing): fail the pool's
        // in-flight sessions with a structured error — the server marks
        // this engine down and retries them elsewhere — then exit; the
        // `AliveGuard` flips `decode_alive` so the admission helper and
        // the `is_alive` pre-check both see the death
        if faults.as_ref().is_some_and(
            |f| f.should_for(FaultSite::EngineKill, index))
        {
            crate::warn!("engine-{index}: injected decode-thread death \
                          ({} in-flight sessions failed)",
                         active.len());
            for a in active.drain(..) {
                metrics.active_sessions.fetch_sub(1, Ordering::Relaxed);
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = a.reply.send(ServeEvent::Done(error_response(
                    a.id,
                    "engine decode thread died mid-round".to_string(),
                )));
            }
            decoding.store(0, Ordering::Relaxed);
            return;
        }
        if active.is_empty() {
            // idle: block for admitted work (or exit once the
            // admission thread has shut down and the channel drained)
            match adm_rx.recv() {
                Ok(w) => {
                    cache_bytes = w.resident_bytes;
                    active.extend(w.ready);
                }
                Err(_) => break,
            }
        }
        // integrate any further waves without blocking a token
        while let Ok(w) = adm_rx.try_recv() {
            cache_bytes = w.resident_bytes;
            active.extend(w.ready);
        }
        decoding.store(active.len(), Ordering::Relaxed);
        if !active.is_empty() {
            let retired =
                decode_round(&model, cache_bytes, &metrics, &mut active);
            if retired > 0 {
                gate.release(retired);
            }
            decoding.store(active.len(), Ordering::Relaxed);
        }
    }
    let _ = admission.join();
    crate::info!("engine-{index} shutting down");
}

/// The admission helper's main loop: reserve decode-pool room, gather a
/// wave from the request queue, run plan → doc-prefill dedup → assemble
/// → attend on its own model (overlapping the decode thread's rounds),
/// and hand the survivors over. Exits when the request queue closes.
#[allow(clippy::too_many_arguments)]
fn admission_main(index: usize, artifacts: PathBuf, cfg: ServingConfig,
                  default_policy: String, metrics: Arc<Metrics>,
                  host: Arc<HostDocCache>,
                  residency: Option<ResidencyHandle>,
                  rx: mpsc::Receiver<Msg>,
                  adm_tx: mpsc::Sender<AdmittedWave>, gate: Arc<Gate>,
                  decoding: Arc<AtomicUsize>,
                  decode_alive: Arc<AtomicBool>,
                  ready_tx: mpsc::Sender<Result<()>>) {
    let init = (|| -> Result<(Model, EngineDocCache)> {
        let rt = std::rc::Rc::new(Runtime::new(artifacts)?);
        let model = Model::load(rt, &cfg.profile)?;
        // the attend stage drives scalar decode steps over the query
        // tokens (common::prefill_query), so the scalar decode entries
        // belong to the admission warmup set too
        model.warmup_entries(&[
            "prefill_doc",
            "query_embed",
            "recompute",
            "decode_sparse",
            "decode_full",
            "score_blocks",
        ])?;
        // residency budget: documents for ~64 concurrent doc-sets
        let budget = 64
            * model.cfg.n_docs
            * model.cfg.doc_len
            * model.cfg.kv_bytes_per_token()
            * 4;
        // an auto-sized host tier is bounded too: hold ~4 engines'
        // worth of residency (explicitly configured budgets win)
        host.ensure_min_budget(budget.saturating_mul(4));
        Ok((model,
            EngineDocCache::new(host, budget).with_residency(residency)))
    })();
    let (model, mut store) = match init {
        Ok(x) => {
            let _ = ready_tx.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let policies = policy_table();
    let window = Duration::from_millis(cfg.batch_window_ms);
    let wave_cap = cfg.max_batch.max(1);
    loop {
        // wait for decode-pool room before pulling requests off the
        // queue (slots free as the decode thread retires sessions);
        // observe-then-take is race-free: only this thread debits. A
        // dead decode thread frees no slots — bail instead of spinning
        // forever (and wedging Engine::drop) on a pool that can never
        // drain.
        let free = loop {
            let f = gate.wait_available(Duration::from_millis(50));
            if f > 0 {
                break f;
            }
            if !decode_alive.load(Ordering::Relaxed) {
                return;
            }
        };
        let Some(wave) = next_batch(&rx, free.min(wave_cap), window)
        else {
            break; // request queue closed: shut down
        };
        gate.take(wave.len());
        let t = Instant::now();
        let busy_before = decoding.load(Ordering::Relaxed) > 0;
        let (ready, rejected) = admit_wave(index, &cfg, &model,
                                           &mut store, policies,
                                           &default_policy, &metrics,
                                           wave);
        if rejected > 0 {
            gate.release(rejected);
        }
        // admission time that ran beside in-flight decode rounds — the
        // overlap the helper thread exists for (endpoint sampling: a
        // wave counts fully when the decode pool was busy at its start
        // or end)
        if busy_before || decoding.load(Ordering::Relaxed) > 0 {
            metrics
                .record_assemble_overlap(t.elapsed().as_secs_f64() * 1e3);
        }
        let resident_bytes = store.stats().current_bytes;
        if ready.is_empty() {
            continue;
        }
        if let Err(mpsc::SendError(wave)) =
            adm_tx.send(AdmittedWave { ready, resident_bytes })
        {
            // decode thread gone (abnormal): answer the wave's clients
            // and return their pool slots instead of stranding both
            let n = wave.ready.len();
            for a in wave.ready {
                metrics.active_sessions.fetch_sub(1, Ordering::Relaxed);
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = a.reply.send(ServeEvent::Done(error_response(
                    a.id,
                    "engine decode thread unavailable".to_string(),
                )));
            }
            gate.release(n);
            break;
        }
    }
}

/// The process-wide policy table. Sessions handed from the admission
/// thread to the decode thread borrow their policy, so the table must
/// outlive every engine's threads: policies are stateless, so one
/// lazily-built `'static` table serves every engine spawn (no per-spawn
/// leak, no `Arc` threaded through every session).
fn policy_table() -> &'static HashMap<String, Box<dyn ContextPolicy>> {
    static TABLE: OnceLock<HashMap<String, Box<dyn ContextPolicy>>> =
        OnceLock::new();
    TABLE.get_or_init(|| {
        all_policies().into_iter().map(|p| (p.name(), p)).collect()
    })
}

/// Locate one shared document's token ids through its first *live*
/// sharer's plan (a plan's `doc_hashes` mirror its sample's doc order
/// — never through a fixed request index, which goes stale when that
/// request is rejected earlier in the wave). One definition serves
/// both the disk prefetch and the prefill loop so the invariant
/// cannot drift. `None` when every sharer already died.
fn shared_doc_tokens<'s>(
    sessions: &'s [Option<ServeSession<'static, dyn ContextPolicy>>],
    sd: &SharedDoc,
) -> Option<&'s [i32]> {
    let s = sd
        .sharers
        .iter()
        .find_map(|&si| sessions.get(si)?.as_ref())?;
    let dj = s.plan().doc_hashes.iter().position(|&h| h == sd.hash)?;
    Some(s.sample().docs.get(dj)?.as_slice())
}

fn error_response(id: u64, msg: String) -> ServeResponse {
    ServeResponse {
        id,
        answer: vec![],
        stats: Default::default(),
        error: Some(msg),
    }
}

/// Admit one wave of queued requests: plan every request, dedup shared
/// document prefills across the wave, then run each survivor's
/// prefill/assemble/attend. Requests that fail any stage are answered
/// with an error immediately; survivors are returned for the decode
/// pool (appended at the back — round-robin order is arrival order).
/// Requests whose `--request-timeout-ms` deadline already passed while
/// queued are failed with a structured timeout error before any model
/// work is spent on them. Returns `(survivors, rejected_count)`.
#[allow(clippy::too_many_arguments)]
fn admit_wave(index: usize, cfg: &ServingConfig, model: &Model,
              store: &mut EngineDocCache,
              policies: &'static HashMap<String, Box<dyn ContextPolicy>>,
              default_policy: &str, metrics: &Metrics, wave: Vec<Msg>)
              -> (Vec<Active>, usize) {
    // --- stage 1: plan every request (pure, model-free) ---------------
    let n = wave.len();
    let mut items: Vec<(u64, bool, mpsc::Sender<ServeEvent>,
                        Option<Instant>)> = Vec::with_capacity(n);
    let mut sessions: Vec<Option<ServeSession<'static, dyn ContextPolicy>>> =
        Vec::with_capacity(n);
    for msg in wave {
        let Msg::Serve(req, reply, submitted) = msg;
        let ServeRequest { id, sample, policy, stream } = req;
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        let queue_wait_ms = submitted.elapsed().as_secs_f64() * 1e3;
        metrics.queue_wait.observe_ms(queue_wait_ms);
        let deadline = (cfg.request_timeout_ms > 0).then(|| {
            submitted + Duration::from_millis(cfg.request_timeout_ms)
        });
        if deadline.is_some_and(|d| Instant::now() >= d) {
            metrics.timeouts.fetch_add(1, Ordering::Relaxed);
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(ServeEvent::Done(error_response(
                id,
                format!("request timed out after {}ms (queued)",
                        cfg.request_timeout_ms),
            )));
            sessions.push(None);
            items.push((id, stream, reply, deadline));
            continue;
        }
        let pname = if policy.is_empty() {
            default_policy
        } else {
            policy.as_str()
        };
        match policies.get(pname) {
            Some(p) => {
                let mut s =
                    ServeSession::new(p.as_ref(), &model.cfg, sample);
                s.set_queue_wait(queue_wait_ms);
                sessions.push(Some(s));
            }
            None => {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(ServeEvent::Done(error_response(
                    id, format!("unknown policy `{pname}`"))));
                sessions.push(None);
            }
        }
        items.push((id, stream, reply, deadline));
    }

    // --- stage 2: cross-request doc-prefill dedup ----------------------
    // prefill each document needed by the wave exactly once; split the
    // cost across the requests sharing it. The whole wave's planned
    // hashes are pinned for the duration so no tier eviction can race
    // the per-session stages below.
    let shared = {
        let plans: Vec<Option<&crate::policies::ServePlan>> = sessions
            .iter()
            .map(|s| s.as_ref().map(|s| s.plan()))
            .collect();
        dedup_doc_plans(&plans)
    };
    let _wave_pins = {
        let hashes: Vec<u64> = shared.iter().map(|sd| sd.hash).collect();
        store.pin_planned(&hashes)
    };
    // disk prefetch: pull the wave's planned documents off the
    // persistent tier (if attached) into the host tier before the
    // prefill pass. This runs on the admission thread while the decode
    // thread keeps emitting tokens, so disk load latency overlaps
    // decode compute exactly like assemble does; the prefill loop
    // below then sees resident/host hits instead of paying the model.
    {
        let docs: Vec<(u64, &[i32])> = shared
            .iter()
            .filter_map(|sd| {
                shared_doc_tokens(&sessions, sd).map(|t| (sd.hash, t))
            })
            .collect();
        store.prefetch_from_disk(&docs);
    }
    for sd in &shared {
        // sharers may have died earlier in this stage (a previous doc's
        // prefill failed); don't prefill for nobody, and split the cost
        // over the requests actually served
        let live: Vec<usize> = sd
            .sharers
            .iter()
            .copied()
            .filter(|&si| sessions.get(si).is_some_and(|s| s.is_some()))
            .collect();
        if live.is_empty() {
            continue;
        }
        let t = Instant::now();
        let hit = match shared_doc_tokens(&sessions, sd) {
            // the live-sharer invariant should hold (live sharers were
            // filtered above and plans mirror doc order), but a
            // violation must fail this doc's requests — not panic the
            // admission thread and strand every queued client
            None => Err(anyhow::anyhow!(
                "shared doc {:016x} has no live sharer plan", sd.hash)),
            Some(_)
                if cfg.fault_plan.as_ref().is_some_and(|f| {
                    f.should_for(FaultSite::DocPrefill, index)
                }) =>
            {
                Err(anyhow::anyhow!("injected doc-prefill fault"))
            }
            Some(tokens) => store.get_or_prefill(model, tokens),
        };
        match hit {
            // already resident: free
            Ok((_, TierHit::Resident)) => continue,
            // host-, disk-, or peer-tier hit — but the lookup may have
            // blocked on another engine's in-flight prefill lease, or
            // paid a disk load / peer fetch the prefetch missed;
            // attribute that wait to the sharers' doc_prefill time
            // (cache still warm: no local model prefill ran)
            Ok((_, TierHit::Host))
            | Ok((_, TierHit::Disk))
            | Ok((_, TierHit::Peer)) => {
                let share =
                    t.elapsed().as_secs_f64() * 1e3 / live.len() as f64;
                for &si in &live {
                    if let Some(s) =
                        sessions.get_mut(si).and_then(|s| s.as_mut())
                    {
                        s.credit_shared_prefill(share, false);
                    }
                }
                continue;
            }
            Ok((_, TierHit::Prefilled)) => {}
            Err(e) => {
                // fail every live sharer now rather than re-running the
                // (expensive, failing) prefill once per request later
                for &si in &live {
                    if let Some(slot) = sessions.get_mut(si) {
                        *slot = None;
                    }
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    if let Some((id, _, reply, _)) = items.get(si) {
                        let _ =
                            reply.send(ServeEvent::Done(error_response(
                                *id,
                                format!("doc prefill failed: {e:#}"),
                            )));
                    }
                }
                continue;
            }
        }
        metrics.doc_prefills.fetch_add(1, Ordering::Relaxed);
        let share = t.elapsed().as_secs_f64() * 1e3 / live.len() as f64;
        for &si in &live {
            if let Some(s) = sessions.get_mut(si).and_then(|s| s.as_mut())
            {
                s.credit_shared_prefill(share, true);
            }
        }
    }

    // --- stage 3: per-request prefill (cache hits) + assemble + attend
    for (slot, (id, _, reply, _)) in sessions.iter_mut().zip(&items) {
        let staged = {
            let Some(s) = slot.as_mut() else {
                continue;
            };
            (|| -> Result<()> {
                s.prefill_docs(model, store)?;
                s.assemble(model)?;
                s.attend(model)
            })()
        };
        if let Err(e) = staged {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(ServeEvent::Done(error_response(
                *id, format!("{e:#}"))));
            *slot = None;
        }
    }

    // flush per-tier cache counters after every admission wave — decode
    // never touches the doc cache, and under continuous admission there
    // is no "end of batch" to flush at, so this is the only point where
    // the counters stay in lockstep with responses
    metrics.record_cache_tiers(&store.host_stats(),
                               &store.take_stats_delta());
    if let Some(disk) = store.host().disk() {
        metrics.record_disk_tier(&disk.stats(),
                                 &disk.take_load_samples());
    }
    metrics.record_pool(&store.host().pool().stats());
    let codec = store.host().pool().codec();
    metrics.record_codec(&codec.stats().snapshot(codec.name()),
                         &codec.stats().take_decode_samples());
    if let Some(plan) = cfg.fault_plan.as_deref() {
        metrics.record_faults(plan);
    }

    // --- survivors go to the decode pool -------------------------------
    let mut ready = Vec::with_capacity(sessions.len());
    for ((id, stream, reply, deadline), s) in
        items.into_iter().zip(sessions)
    {
        if let Some(session) = s {
            metrics.active_sessions.fetch_add(1, Ordering::Relaxed);
            ready.push(Active { id, stream, reply, deadline, session });
        }
    }
    let rejected = n - ready.len();
    (ready, rejected)
}

/// One fused decode round over the pool: every session emits at most
/// one token (round-robin in pool order), all requested forward passes
/// run as one [`Model::decode_batch`] call — which issues a single
/// lane-padded XLA execution per same-buffer chunk — and finished or
/// failed sessions are retired (after the round's token emissions, so a
/// round's `Done` events never precede its tokens). Returns how many
/// sessions were retired (their pool slots go back to the admission
/// gate).
fn decode_round(model: &Model, cache_bytes: usize, metrics: &Metrics,
                active: &mut Vec<Active>) -> usize {
    // --- emit: at most one token per session ---------------------------
    let mut pending: Vec<(usize, FusedStep)> = Vec::new();
    let mut finished: Vec<usize> = Vec::new();
    let mut dead: Vec<(usize, String)> = Vec::new();
    for (i, a) in active.iter_mut().enumerate() {
        let Active { id, stream, reply, deadline, session } = a;
        // deadline sweep: a session past its `--request-timeout-ms`
        // deadline is retired with a structured timeout error instead
        // of decoding (and billing the client) forever
        if deadline.is_some_and(|d| Instant::now() >= d) {
            metrics.timeouts.fetch_add(1, Ordering::Relaxed);
            dead.push((i, "request timed out during decode".to_string()));
            continue;
        }
        let (id, stream) = (*id, *stream);
        let index = session.answer().len();
        let mut sink = FnSink(|token: i32| {
            if stream {
                let _ = reply.send(ServeEvent::Token { id, index, token });
            }
        });
        match session.decode_step_begin(&mut sink) {
            Ok((_, Some(step))) => pending.push((i, step)),
            Ok((_, None)) => finished.push(i),
            Err(e) => dead.push((i, format!("{e:#}"))),
        }
    }

    // --- one fused dispatch for every session that wants logits --------
    let mut reqs: Vec<DecodeReq> = Vec::with_capacity(pending.len());
    let mut dispatch: Vec<(usize, FusedStep)> =
        Vec::with_capacity(pending.len());
    for &(i, step) in &pending {
        let Some(a) = active.get_mut(i) else {
            continue;
        };
        match a.session.decode_inputs() {
            Ok((buffer, kv, kv_valid)) => {
                reqs.push(DecodeReq {
                    buffer,
                    token: step.token,
                    pos: step.pos,
                    slot: step.slot as i32,
                    kv,
                    kv_valid,
                });
                dispatch.push((i, step));
            }
            Err(e) => dead.push((i, format!("{e:#}"))),
        }
    }
    if !dispatch.is_empty() {
        let t = Instant::now();
        let round = model.decode_batch(&reqs);
        drop(reqs);
        metrics.record_decode_round(dispatch.len() as u64,
                                    round.executions, round.lanes_live,
                                    round.lanes_total);
        let share =
            t.elapsed().as_secs_f64() * 1e3 / dispatch.len() as f64;
        // per-request outcomes: a failing session is retired alone and
        // never poisons the rest of the round
        for (&(i, step), out) in dispatch.iter().zip(round.results) {
            let Some(a) = active.get_mut(i) else {
                continue;
            };
            let folded = out.and_then(|o| {
                a.session.decode_step_complete(step, o, share)
            });
            if let Err(e) = folded {
                dead.push((i, format!("{e:#}")));
            }
        }
    }

    // --- retire finished/failed sessions (descending index keeps the
    // remaining pool's round-robin order stable) ------------------------
    let mut retire: Vec<(usize, Option<String>)> = finished
        .into_iter()
        .map(|i| (i, None))
        .chain(dead.into_iter().map(|(i, e)| (i, Some(e))))
        .collect();
    retire.sort_by_key(|r| std::cmp::Reverse(r.0));
    let retired = retire.len();
    for (i, err) in retire {
        let a = active.remove(i);
        metrics.active_sessions.fetch_sub(1, Ordering::Relaxed);
        match err {
            None => {
                let out = a.session.finish();
                metrics.record_completion(
                    out.stats.ttft_ms,
                    out.stats.decode_ms,
                    out.answer.len(),
                    cache_bytes,
                );
                metrics.record_stage_times(out.stats.plan_ms,
                                           out.stats.doc_prefill_ms);
                let _ = a.reply.send(ServeEvent::Done(ServeResponse {
                    id: a.id,
                    answer: out.answer,
                    stats: out.stats,
                    error: None,
                }));
            }
            Some(msg) => {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = a.reply.send(ServeEvent::Done(error_response(
                    a.id, msg)));
            }
        }
    }
    retired
}
