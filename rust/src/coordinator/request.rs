//! Request/response types crossing the server <-> engine boundary.

use crate::json::Value;
use crate::policies::RunStats;
use crate::workload::Sample;

/// An admitted serving request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub sample: Sample,
    /// Policy table name (e.g. "SamKV-fusion"); empty = engine default.
    pub policy: String,
}

impl ServeRequest {
    /// Parse the JSON-lines wire format:
    /// `{"id":1,"docs":[[...]],"query":[...],"policy":"SamKV-fusion"}`.
    pub fn from_json(v: &Value) -> crate::Result<ServeRequest> {
        let docs = v
            .req("docs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("docs not an array"))?
            .iter()
            .map(|d| {
                d.i32_vec()
                    .ok_or_else(|| anyhow::anyhow!("bad doc tokens"))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(ServeRequest {
            id: v.get("id").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
            sample: Sample {
                docs,
                query: v
                    .req("query")?
                    .i32_vec()
                    .ok_or_else(|| anyhow::anyhow!("bad query"))?,
                answer: Vec::new(),
                qtype: "served".to_string(),
            },
            policy: v
                .get("policy")
                .and_then(|p| p.as_str())
                .unwrap_or("")
                .to_string(),
        })
    }
}

/// The engine's reply.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    pub answer: Vec<i32>,
    pub stats: RunStats,
    pub error: Option<String>,
}

impl ServeResponse {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .set("id", self.id as i64)
            .set(
                "answer",
                Value::Arr(
                    self.answer.iter().map(|&t| (t as i64).into()).collect(),
                ),
            )
            .set("ttft_ms", self.stats.ttft_ms)
            .set("decode_ms", self.stats.decode_ms)
            .set("seq_ratio", self.stats.seq_ratio)
            .set("recompute_ratio", self.stats.recompute_ratio)
            .set("kv_bytes", self.stats.kv_bytes)
            .set("cache_warm", self.stats.cache_warm);
        if let Some(e) = &self.error {
            v = v.set("error", e.as_str());
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn parse_wire_request() {
        let v = json::parse(
            r#"{"id":7,"docs":[[1,2],[3,4]],"query":[2,5,16,0,3],
                "policy":"Reuse"}"#,
        )
        .unwrap();
        let r = ServeRequest::from_json(&v).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.sample.docs.len(), 2);
        assert_eq!(r.policy, "Reuse");
    }

    #[test]
    fn parse_rejects_malformed() {
        let v = json::parse(r#"{"id":1,"query":[1]}"#).unwrap();
        assert!(ServeRequest::from_json(&v).is_err());
        let v = json::parse(r#"{"docs":[["x"]],"query":[1]}"#).unwrap();
        assert!(ServeRequest::from_json(&v).is_err());
    }

    #[test]
    fn response_serializes() {
        let r = ServeResponse {
            id: 3,
            answer: vec![80, 81],
            stats: Default::default(),
            error: None,
        };
        let s = r.to_json().to_string();
        assert!(s.contains("\"id\":3"));
        assert!(s.contains("\"answer\":[80,81]"));
        assert!(!s.contains("error"));
    }
}
