//! Request/response types crossing the server <-> engine boundary.

use std::sync::mpsc;

use crate::json::Value;
use crate::policies::RunStats;
use crate::workload::Sample;

/// An admitted serving request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub sample: Sample,
    /// Policy table name (e.g. "SamKV-fusion"); empty = engine default.
    pub policy: String,
    /// Stream tokens as they decode ([`ServeEvent::Token`] events
    /// before the terminal [`ServeEvent::Done`]).
    pub stream: bool,
}

impl ServeRequest {
    /// Parse the JSON-lines wire format:
    /// `{"id":1,"docs":[[...]],"query":[...],"policy":"SamKV-fusion",
    ///   "stream":true}`.
    pub fn from_json(v: &Value) -> crate::Result<ServeRequest> {
        let docs = v
            .req("docs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("docs not an array"))?
            .iter()
            .map(|d| {
                d.i32_vec()
                    .ok_or_else(|| anyhow::anyhow!("bad doc tokens"))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(ServeRequest {
            id: v.get("id").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
            sample: Sample {
                docs,
                query: v
                    .req("query")?
                    .i32_vec()
                    .ok_or_else(|| anyhow::anyhow!("bad query"))?,
                answer: Vec::new(),
                qtype: "served".to_string(),
            },
            policy: v
                .get("policy")
                .and_then(|p| p.as_str())
                .unwrap_or("")
                .to_string(),
            stream: v
                .get("stream")
                .and_then(|s| s.as_bool())
                .unwrap_or(false),
        })
    }
}

/// The engine's terminal reply.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    pub answer: Vec<i32>,
    pub stats: RunStats,
    pub error: Option<String>,
}

impl ServeResponse {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .set("id", self.id as i64)
            .set(
                "answer",
                Value::Arr(
                    self.answer.iter().map(|&t| (t as i64).into()).collect(),
                ),
            )
            .set("ttft_ms", self.stats.ttft_ms)
            .set("decode_ms", self.stats.decode_ms)
            .set("plan_ms", self.stats.plan_ms)
            .set("queue_wait_ms", self.stats.queue_wait_ms)
            .set("doc_prefill_ms", self.stats.doc_prefill_ms)
            .set("seq_ratio", self.stats.seq_ratio)
            .set("recompute_ratio", self.stats.recompute_ratio)
            .set("kv_bytes", self.stats.kv_bytes)
            .set("cache_warm", self.stats.cache_warm);
        if let Some(e) = &self.error {
            v = v.set("error", e.as_str());
        }
        v
    }
}

/// One message on a request's reply channel. Non-streaming requests
/// only ever see [`ServeEvent::Done`]; streaming requests see one
/// [`ServeEvent::Token`] per generated token first.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// One decoded answer token, emitted as soon as it exists.
    Token { id: u64, index: usize, token: i32 },
    /// Terminal event: the full response (or error).
    Done(ServeResponse),
}

impl ServeEvent {
    pub fn to_json(&self) -> Value {
        match self {
            ServeEvent::Token { id, index, token } => Value::obj()
                .set("id", *id as i64)
                .set("index", *index as i64)
                .set("token", *token as i64),
            ServeEvent::Done(resp) => resp.to_json(),
        }
    }
}

/// Drain a reply channel until the terminal event, discarding any
/// streamed tokens (the blocking-caller path).
pub fn recv_done(rx: &mpsc::Receiver<ServeEvent>)
                 -> crate::Result<ServeResponse> {
    loop {
        match rx.recv() {
            Ok(ServeEvent::Done(resp)) => return Ok(resp),
            Ok(ServeEvent::Token { .. }) => continue,
            Err(_) => anyhow::bail!("engine dropped reply"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn parse_wire_request() {
        let v = json::parse(
            r#"{"id":7,"docs":[[1,2],[3,4]],"query":[2,5,16,0,3],
                "policy":"Reuse"}"#,
        )
        .unwrap();
        let r = ServeRequest::from_json(&v).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.sample.docs.len(), 2);
        assert_eq!(r.policy, "Reuse");
        assert!(!r.stream); // default: no streaming
    }

    #[test]
    fn parse_stream_flag() {
        let v = json::parse(
            r#"{"id":1,"docs":[[1]],"query":[2],"stream":true}"#,
        )
        .unwrap();
        assert!(ServeRequest::from_json(&v).unwrap().stream);
    }

    #[test]
    fn parse_rejects_malformed() {
        let v = json::parse(r#"{"id":1,"query":[1]}"#).unwrap();
        assert!(ServeRequest::from_json(&v).is_err());
        let v = json::parse(r#"{"docs":[["x"]],"query":[1]}"#).unwrap();
        assert!(ServeRequest::from_json(&v).is_err());
    }

    #[test]
    fn response_serializes() {
        let r = ServeResponse {
            id: 3,
            answer: vec![80, 81],
            stats: Default::default(),
            error: None,
        };
        let s = r.to_json().to_string();
        assert!(s.contains("\"id\":3"));
        assert!(s.contains("\"answer\":[80,81]"));
        assert!(s.contains("plan_ms"));
        assert!(s.contains("queue_wait_ms"));
        assert!(s.contains("doc_prefill_ms"));
        assert!(!s.contains("error"));
    }

    #[test]
    fn token_event_serializes() {
        let e = ServeEvent::Token { id: 2, index: 1, token: 81 };
        let s = e.to_json().to_string();
        assert!(s.contains("\"token\":81"), "{s}");
        assert!(s.contains("\"index\":1"), "{s}");
    }

    #[test]
    fn recv_done_skips_tokens() {
        let (tx, rx) = mpsc::channel();
        tx.send(ServeEvent::Token { id: 1, index: 0, token: 80 })
            .unwrap();
        tx.send(ServeEvent::Done(ServeResponse {
            id: 1,
            answer: vec![80],
            stats: Default::default(),
            error: None,
        }))
        .unwrap();
        let resp = recv_done(&rx).unwrap();
        assert_eq!(resp.answer, vec![80]);
        drop(tx);
        assert!(recv_done(&rx).is_err());
    }
}
