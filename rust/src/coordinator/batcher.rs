//! Dynamic batching: drain up to `max_batch` queued requests within a
//! short gather window so the engine amortizes per-wakeup overhead
//! while bounding added latency.
//!
//! Two gather shapes feed the continuous-batching scheduler
//! ([`crate::coordinator::engine`]): [`next_batch`] blocks for the
//! first request (the engine is idle, nothing better to do), while
//! [`poll_batch`] never blocks on an empty queue — it is called
//! between decode rounds, where stalling would hold up every active
//! session's next token.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Pull one batch from `rx`. Blocks for the first item (or returns None
/// when the channel is closed), then gathers more items until either
/// `max_batch` is reached or `window` elapses.
pub fn next_batch<T>(rx: &Receiver<T>, max_batch: usize,
                     window: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + window;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Non-blocking gather for mid-round admission. If the queue is empty
/// the call returns immediately with no items; once a first item is in
/// hand, more are gathered until `max_batch` or the `window` deadline —
/// the same coalescing rule as [`next_batch`], without ever paying the
/// window on an idle queue. The second element of the return value is
/// `false` once the channel has disconnected (all senders dropped),
/// which the engine uses to begin draining toward shutdown.
pub fn poll_batch<T>(rx: &Receiver<T>, max_batch: usize,
                     window: Duration) -> (Vec<T>, bool) {
    let first = match rx.try_recv() {
        Ok(item) => item,
        Err(TryRecvError::Empty) => return (Vec::new(), true),
        Err(TryRecvError::Disconnected) => return (Vec::new(), false),
    };
    let mut batch = vec![first];
    let deadline = Instant::now() + window;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => return (batch, false),
        }
    }
    (batch, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = next_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn window_bounds_waiting() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, 16, Duration::from_millis(20)).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn disconnect_mid_gather_returns_partial_batch() {
        // first item arrives, then the sender closes before max_batch:
        // the gathered partial batch is still delivered (not dropped),
        // and the NEXT call observes the closed channel
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let b = next_batch(&rx, 8, Duration::from_millis(50)).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(next_batch(&rx, 8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn deadline_flushes_partial_batch_under_max() {
        // a slow trickle never fills max_batch; the window deadline
        // flushes whatever was gathered so latency stays bounded
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, 64, Duration::from_millis(10)).unwrap();
        assert_eq!(b, vec![7]);
        assert!(t0.elapsed() >= Duration::from_millis(5));
        drop(tx);
    }

    #[test]
    fn poll_returns_immediately_on_empty_queue() {
        let (tx, rx) = mpsc::channel::<u32>();
        let t0 = Instant::now();
        let (b, open) = poll_batch(&rx, 8, Duration::from_millis(250));
        assert!(b.is_empty());
        assert!(open);
        // never waited for the window: the queue was empty
        assert!(t0.elapsed() < Duration::from_millis(200));
        drop(tx);
        let (b, open) = poll_batch(&rx, 8, Duration::from_millis(1));
        assert!(b.is_empty());
        assert!(!open, "disconnected channel must be reported closed");
    }

    #[test]
    fn poll_gathers_queued_items_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let (b, open) = poll_batch(&rx, 3, Duration::from_millis(5));
        assert_eq!(b, vec![0, 1, 2]);
        assert!(open);
        let (b, open) = poll_batch(&rx, 8, Duration::from_millis(5));
        assert_eq!(b, vec![3, 4]);
        assert!(open);
    }

    #[test]
    fn poll_reports_disconnect_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        drop(tx);
        let (b, open) = poll_batch(&rx, 4, Duration::from_millis(20));
        assert_eq!(b, vec![7]);
        assert!(!open);
    }

    #[test]
    fn gathers_late_arrivals_within_window() {
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(1).unwrap();
        });
        let b = next_batch(&rx, 4, Duration::from_millis(100)).unwrap();
        t.join().unwrap();
        assert_eq!(b.len(), 2);
    }
}
