//! Dynamic batching: drain up to `max_batch` queued requests within a
//! short gather window so the engine amortizes per-wakeup overhead
//! while bounding added latency.
//!
//! [`next_batch`] blocks for the first request — since admission moved
//! to its own helper thread ([`crate::coordinator::engine`]), blocking
//! here never stalls a decode round, so it is the scheduler's only
//! gather. (The pre-overlap engine also had a non-blocking `poll_batch`
//! for mid-round admission; it died with that scheduler shape.)

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Pull one batch from `rx`. Blocks for the first item (or returns None
/// when the channel is closed), then gathers more items until either
/// `max_batch` is reached or `window` elapses.
pub fn next_batch<T>(rx: &Receiver<T>, max_batch: usize,
                     window: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + window;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = next_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn window_bounds_waiting() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, 16, Duration::from_millis(20)).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn disconnect_mid_gather_returns_partial_batch() {
        // first item arrives, then the sender closes before max_batch:
        // the gathered partial batch is still delivered (not dropped),
        // and the NEXT call observes the closed channel
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let b = next_batch(&rx, 8, Duration::from_millis(50)).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(next_batch(&rx, 8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn deadline_flushes_partial_batch_under_max() {
        // a slow trickle never fills max_batch; the window deadline
        // flushes whatever was gathered so latency stays bounded
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, 64, Duration::from_millis(10)).unwrap();
        assert_eq!(b, vec![7]);
        assert!(t0.elapsed() >= Duration::from_millis(5));
        drop(tx);
    }

    #[test]
    fn gathers_late_arrivals_within_window() {
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(1).unwrap();
        });
        let b = next_batch(&rx, 4, Duration::from_millis(100)).unwrap();
        t.join().unwrap();
        assert_eq!(b.len(), 2);
    }
}
