//! L3 coordinator: the serving control plane.
//!
//! PJRT clients are not `Send`, so each [`engine::Engine`] owns its
//! runtime + model + document-cache residency tier on a dedicated
//! thread (the vLLM executor-thread pattern), all engines sharing one
//! [`crate::kvcache::HostDocCache`] beneath; [`router::Router`] spreads
//! requests across engines with cache-aware placement (residency →
//! affinity → least-loaded), and [`batcher`] shapes the per-engine
//! queue into bounded admission waves. Each engine runs a persistent
//! continuous-batching scheduler: new requests are admitted between
//! decode rounds (never behind a draining batch) and each round's
//! forward passes are fused into one amortized dispatch — see
//! [`engine`] for the lifecycle.

pub mod batcher;
pub mod engine;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineHandle};
pub use request::{recv_done, ServeEvent, ServeRequest, ServeResponse};
pub use router::Router;
