//! L3 coordinator: the serving control plane.
//!
//! PJRT clients are not `Send`, so each [`engine::Engine`] runs a pair
//! of dedicated threads — a decode thread and an admission helper, each
//! owning its own runtime/model (the vLLM executor-thread pattern,
//! split by stage) — all engines sharing one
//! [`crate::kvcache::HostDocCache`] beneath; [`router::Router`] spreads
//! requests across engines with cache-aware placement (residency →
//! affinity → least-loaded), and [`batcher`] shapes the per-engine
//! queue into bounded admission waves. Each engine runs a persistent
//! continuous-batching scheduler: newcomers plan/prefill/assemble on
//! the admission thread *while* the decode thread keeps emitting
//! tokens, and each round's forward passes are packed into the
//! lane-padded batched decode artifacts — one XLA execution per
//! same-buffer chunk — see [`engine`] for the lifecycle.
//!
//! # Fault-tolerance contract
//!
//! The control plane is supervised; an engine death is an event, not
//! an outage:
//!
//! * **Liveness is observable.** Each engine exposes
//!   [`engine::EngineHandle::is_alive`], flipped false the instant its
//!   decode thread exits for any reason — crash, panic unwind, or an
//!   injected [`crate::faultinject::FaultSite::EngineKill`]. The
//!   admission helper watches the same flag so it can never wedge on a
//!   decode pool that will not drain.
//! * **Death produces terminal replies, never silence.** A dying
//!   decode thread fails its in-flight sessions with structured
//!   `"engine decode thread died mid-round"` errors; the admission
//!   helper answers any wave it cannot hand over. Every submitted
//!   request reaches a terminal event or a closed channel — no path
//!   leaves a client waiting forever.
//! * **The router learns.** [`router::Router::mark_down`] takes a dead
//!   engine out of every placement stage and clears its residency
//!   advertisements; [`router::Router::mark_up`] restores it. With
//!   every engine down, placement falls back to all so requests fail
//!   with structured errors rather than panicking.
//! * **The server retries.** The TCP front end resubmits delivery
//!   failures (and only those — never after a token was streamed) to
//!   surviving engines with jittered exponential backoff, under the
//!   per-request `--request-timeout-ms` deadline, which is enforced
//!   across queue wait, admission, and every decode round. See
//!   [`crate::server`].
//!
//! All of it is exercised deterministically by `--fault-plan`
//! ([`crate::faultinject`]) and observable through
//! [`crate::metrics::Metrics`] (`retries`, `retry_successes`,
//! `timeouts`, `engine_down_events`, `engines_down`).
//!
//! # Peer ownership (`--peers` mode)
//!
//! In a multi-node cluster the same contract extends across
//! processes. Every node derives the same document owner from the
//! content hash alone —
//! [`crate::server::peers::rendezvous_owner`], no coordination, no
//! ownership table — so the coordinator's placement story has a
//! cluster-level analogue: the in-process router steers a request to
//! the engine already holding its documents, and the peer tier steers
//! a host-tier miss to the *node* that owns it. On such a miss the
//! engine's admission thread, already holding the per-document
//! prefill lease, asks the owner for the serialized entry
//! ([`crate::kvcache::TierHit::Peer`]) before paying a model prefill;
//! concurrent engines and concurrent nodes alike coalesce on the
//! lease, which is what makes the exactly-once prefill guarantee
//! cluster-wide.
//!
//! The degradation contract mirrors the engine-death one: a peer
//! fetch can fail (connection refused, timeout, checksum mismatch,
//! injected [`crate::faultinject::FaultSite::PeerFetch`]) and every
//! failure is *a cache miss, never a failed request* — the admission
//! thread falls through to a local prefill under the same lease, and
//! the dead peer sits in a down-cooldown so subsequent misses
//! fail-fast instead of re-paying the connect timeout. The optional
//! [`crate::server::front::FrontEnd`] applies the router's own
//! mark-down/retry discipline one level up, across whole nodes.
//!
//! # Concurrency invariants & how to verify them
//!
//! The control plane's threading model is ownership-first: each
//! engine's decode thread and admission helper own their PJRT
//! runtime, model, and session table outright and exchange work over
//! `mpsc` channels — no lock is ever held across a forward pass.
//! What little shared state exists goes through the [`crate::sync`]
//! facade or atomics:
//!
//! * [`router::Router`] placement state: per-engine load/liveness as
//!   atomics, residency reads via the lock-free
//!   [`crate::kvcache::ResidencyBoard`] snapshot;
//! * the admission gate (`gate-slots` class, [`crate::exec::Gate`]):
//!   a counted-permit condvar between the decode thread freeing pool
//!   slots and the admission helper debiting them — permits are
//!   conserved (loom-modeled), so admission can stall but never
//!   over-admit or deadlock;
//! * the KV tiers beneath every engine: see the "Concurrency
//!   invariants" section of [`crate::kvcache`] for the lock classes,
//!   the canonical acquisition order, and the exactly-once lease
//!   contract the engines rely on.
//!
//! The request-path invariant enforced by tooling: **no panics** —
//! every engine-index, session-slot, or channel failure maps to a
//! structured error event (`tools/lint` denies `unwrap`/`expect`/
//! `panic!`/indexing in this tree, and this module clippy-denies
//! `unwrap_used`/`expect_used`). Verify locally with
//! `RUSTFLAGS="--cfg loom" cargo test --release --test loom_models`
//! (gate + lease models), `SAMKV_LOCKCHECK=1 cargo test` (lock-order
//! cycles), and `tools/lint`.

// Serving-critical tree: see the doc section above.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod batcher;
pub mod engine;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineHandle};
pub use request::{recv_done, ServeEvent, ServeRequest, ServeResponse};
pub use router::Router;
