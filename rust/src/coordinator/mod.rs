//! L3 coordinator: the serving control plane.
//!
//! PJRT clients are not `Send`, so each [`engine::Engine`] runs a pair
//! of dedicated threads — a decode thread and an admission helper, each
//! owning its own runtime/model (the vLLM executor-thread pattern,
//! split by stage) — all engines sharing one
//! [`crate::kvcache::HostDocCache`] beneath; [`router::Router`] spreads
//! requests across engines with cache-aware placement (residency →
//! affinity → least-loaded), and [`batcher`] shapes the per-engine
//! queue into bounded admission waves. Each engine runs a persistent
//! continuous-batching scheduler: newcomers plan/prefill/assemble on
//! the admission thread *while* the decode thread keeps emitting
//! tokens, and each round's forward passes are packed into the
//! lane-padded batched decode artifacts — one XLA execution per
//! same-buffer chunk — see [`engine`] for the lifecycle.

pub mod batcher;
pub mod engine;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineHandle};
pub use request::{recv_done, ServeEvent, ServeRequest, ServeResponse};
pub use router::Router;
