//! Execution substrate: a fixed-size thread pool, a parallel map, and a
//! counting slot [`Gate`] (no tokio offline). The serving stack is
//! thread-per-worker with channels; PJRT executions are blocking calls.
//! The engine pairs two such threads per instance — a decode thread and
//! an admission helper (see `coordinator::engine`) — coordinated by a
//! [`Gate`] over the decode pool's session slots.
//!
//! Concurrency note: the [`Gate`] lives on the [`crate::sync`] facade
//! (loom-model-checked in `tests/loom_models.rs` — permit
//! conservation under racing take/release). The [`ThreadPool`] stays
//! on raw `std::sync` deliberately: loom models never construct one,
//! and its queue mutex (`pool-queue`) is a leaf that nests with
//! nothing.

use std::sync::mpsc;
use std::sync::{Arc, Mutex as StdMutex};
use std::thread;
use std::time::Duration;

use crate::sync::{Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed thread pool. Jobs run FIFO across workers; dropping the pool
/// joins all workers after draining the queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> ThreadPool {
        assert!(size > 0);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(StdMutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            match rx.lock() {
                                Ok(g) => g.recv(),
                                Err(e) => e.into_inner().recv(),
                            }
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker queue closed");
    }

    /// Submit a closure and get a handle to its result.
    pub fn submit<T, F>(&self, f: F) -> Receiver<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        self.execute(move || {
            let _ = tx.send(f());
        });
        Receiver { rx }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Future-ish handle for a submitted job.
pub struct Receiver<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> Receiver<T> {
    pub fn wait(self) -> T {
        self.rx.recv().expect("job panicked or pool dropped")
    }

    pub fn try_wait(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// Counting slot gate between a producer thread that fills a bounded
/// pool and the consumer that drains it. The engine's admission helper
/// observes free decode-pool slots ([`Gate::wait_available`]) before
/// gathering a wave, debits what it admits ([`Gate::take`]), and the
/// decode thread credits slots back as sessions retire
/// ([`Gate::release`]). Observe-then-take is race-free with a single
/// taker: only the taker debits, so the free count can only grow
/// between its observation and its debit.
pub struct Gate {
    slots: Mutex<usize>,
    freed: Condvar,
}

impl Gate {
    pub fn new(slots: usize) -> Gate {
        Gate {
            slots: Mutex::named("gate-slots", slots),
            freed: Condvar::new(),
        }
    }

    /// Currently free slots.
    pub fn available(&self) -> usize {
        *self.slots.lock()
    }

    /// Block until at least one slot is free or `timeout` elapses;
    /// returns the free count observed (0 on timeout).
    pub fn wait_available(&self, timeout: Duration) -> usize {
        let g = self.slots.lock();
        let (g, _) =
            self.freed.wait_timeout_while(g, timeout, |s| *s == 0);
        *g
    }

    /// Debit `n` slots the caller observed free (saturating).
    pub fn take(&self, n: usize) {
        let mut g = self.slots.lock();
        *g = g.saturating_sub(n);
    }

    /// Credit `n` slots back and wake waiters.
    pub fn release(&self, n: usize) {
        {
            let mut g = self.slots.lock();
            *g += n;
        }
        self.freed.notify_all();
    }
}

/// Parallel map with bounded concurrency using scoped threads — used by
/// the eval harness to fan samples across workers without 'static bounds.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    assert!(threads > 0);
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<Mutex<&mut Option<R>>> =
        out.iter_mut().map(Mutex::new).collect();
    crossbeam_utils::thread::scope(|scope| {
        for _ in 0..threads.min(n.max(1)) {
            scope.spawn(|_| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock() = Some(r);
            });
        }
    })
    .expect("scoped threads");
    drop(slots);
    out.into_iter().map(|x| x.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join drains the queue
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_values() {
        let pool = ThreadPool::new(2, "test");
        let handles: Vec<_> =
            (0..10).map(|i| pool.submit(move || i * i)).collect();
        let got: Vec<usize> = handles.into_iter().map(|h| h.wait()).collect();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..50).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn gate_take_and_release_account() {
        let g = Gate::new(3);
        assert_eq!(g.available(), 3);
        g.take(2);
        assert_eq!(g.available(), 1);
        g.take(5); // saturates, never underflows
        assert_eq!(g.available(), 0);
        g.release(4);
        assert_eq!(g.available(), 4);
    }

    #[test]
    fn gate_wait_times_out_empty_and_wakes_on_release() {
        let g = Arc::new(Gate::new(0));
        assert_eq!(
            g.wait_available(std::time::Duration::from_millis(10)),
            0
        );
        let waiter = {
            let g = Arc::clone(&g);
            thread::spawn(move || {
                g.wait_available(std::time::Duration::from_secs(5))
            })
        };
        thread::sleep(std::time::Duration::from_millis(20));
        g.release(2);
        assert_eq!(waiter.join().unwrap(), 2);
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        let out = parallel_map(&[1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<usize> = vec![];
        let out: Vec<usize> = parallel_map(&empty, 4, |&x| x);
        assert!(out.is_empty());
    }
}
