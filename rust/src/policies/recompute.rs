//! "Recompute" baseline: full joint causal prefill of documents + query
//! (maximum quality, maximum TTFT, 100% KV).
//!
//! The only policy whose `assemble` stage feeds the query itself (the
//! joint prefill already covers it), so its `ReadyContext` carries the
//! first answer token's logits and the attend stage is a no-op.

use std::sync::Arc;

use crate::config::ProfileConfig;
use crate::kvcache::{AssembledContext, DocEntry};
use crate::model::{Buffer, Model};
use crate::workload::{assemble_full, Sample};

use super::pipeline::{ReadyContext, ServePlan};
use super::ContextPolicy;

pub struct RecomputePolicy;

impl ContextPolicy for RecomputePolicy {
    fn name(&self) -> String {
        "Recompute".to_string()
    }

    fn uses_doc_cache(&self) -> bool {
        false
    }

    fn plan(&self, cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        let mut plan = ServePlan::docs_only("Recompute", false, sample);
        plan.buffer = Buffer::Full;
        plan.planned_recompute_tokens = cfg.ctx_len;
        plan
    }

    fn assemble(&self, model: &Model, _docs: &[Arc<DocEntry>],
                sample: &Sample) -> crate::Result<ReadyContext> {
        let cfg = model.cfg.clone();
        let (tokens, valid, ans_start) = assemble_full(sample, &cfg);
        let kv = model.prefill_full(&tokens, &valid)?;

        // wrap the joint KV in an assembled context for the decode loop
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        ctx.replace_kv(kv)?;
        ctx.tokens[..ans_start].copy_from_slice(&tokens[..ans_start]);
        for (i, p) in ctx.positions.iter_mut().enumerate() {
            *p = i as i32;
        }
        // query included in the prefill: only slots < ans_start are live
        for s in 0..ans_start {
            ctx.valid[s] = 1.0;
        }
        ctx.cursor = ans_start;
        ctx.kv_len = cfg.ctx_len;

        // first answer token: re-decode the final query token (ANS) to
        // obtain its logits (its KV is recomputed identically in-place)
        let last = ans_start - 1;
        ctx.valid[last] = 0.0; // the decode step re-inserts this slot
        ctx.cursor = last;
        let _ = ctx.push_token(tokens[last], last as i32)?;
        let out = model.decode(Buffer::Full, tokens[last], last as i32,
                               last as i32, &ctx.kv, &ctx.valid)?;
        ctx.write_token_kv(last, &out.k_new, &out.v_new);

        let mut ready = ReadyContext::new(&cfg, ctx, Buffer::Full);
        ready.recompute_ratio = 1.0;
        ready.logits = Some(out.logits); // query already fed
        Ok(ready)
    }
}
