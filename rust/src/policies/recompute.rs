//! "Recompute" baseline: full joint causal prefill of documents + query
//! (maximum quality, maximum TTFT, 100% KV).

use std::time::Instant;

use crate::kvcache::{AssembledContext, CacheStore};
use crate::model::{Buffer, Model};
use crate::workload::{assemble_full, Sample};

use super::{ContextPolicy, PolicyOutput, RunStats};

pub struct RecomputePolicy;

impl ContextPolicy for RecomputePolicy {
    fn name(&self) -> String {
        "Recompute".to_string()
    }

    fn uses_doc_cache(&self) -> bool {
        false
    }

    fn run(&self, model: &Model, _store: &mut CacheStore, sample: &Sample)
           -> crate::Result<PolicyOutput> {
        let cfg = model.cfg.clone();
        let t0 = Instant::now();
        let (tokens, valid, ans_start) = assemble_full(sample, &cfg);
        let kv = model.prefill_full(&tokens, &valid)?;

        // wrap the joint KV in an assembled context for the decode loop
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        ctx.replace_kv(kv)?;
        ctx.tokens[..ans_start].copy_from_slice(&tokens[..ans_start]);
        for (i, p) in ctx.positions.iter_mut().enumerate() {
            *p = i as i32;
        }
        // query included in the prefill: only slots < ans_start are live
        for s in 0..ans_start {
            ctx.valid[s] = 1.0;
        }
        ctx.cursor = ans_start;
        ctx.kv_len = cfg.ctx_len;

        // first answer token: re-decode the final query token (ANS) to
        // obtain its logits (its KV is recomputed identically in-place)
        let last = ans_start - 1;
        ctx.valid[last] = 0.0; // the decode step re-inserts this slot
        ctx.cursor = last;
        let _ = ctx.push_token(tokens[last], last as i32)?;
        let out = model.decode(Buffer::Full, tokens[last], last as i32,
                               last as i32, &ctx.kv, &ctx.valid)?;
        ctx.write_token_kv(last, &out.k_new, &out.v_new);
        let ttft_ms = t0.elapsed().as_secs_f64() * 1e3;

        // greedy decode from these logits
        let td = Instant::now();
        let mut answer = Vec::new();
        let mut cur = Model::argmax(&out.logits);
        let mut pos = ans_start as i32;
        for _ in 0..cfg.answer_max {
            if cur == crate::tokenizer::EOS {
                break;
            }
            answer.push(cur);
            if answer.len() >= cfg.answer_max {
                break;
            }
            let slot = ctx.push_token(cur, pos)?;
            let step = model.decode(Buffer::Full, cur, pos, slot as i32,
                                    &ctx.kv, &ctx.valid)?;
            ctx.write_token_kv(slot, &step.k_new, &step.v_new);
            cur = Model::argmax(&step.logits);
            pos += 1;
        }

        Ok(PolicyOutput {
            answer,
            stats: RunStats {
                ttft_ms,
                decode_ms: td.elapsed().as_secs_f64() * 1e3,
                seq_ratio: 1.0,
                recompute_ratio: 1.0,
                kv_bytes: cfg.ctx_len * cfg.kv_bytes_per_token(),
                cache_warm: false,
            },
        })
    }
}
