//! SamKV (§3): sparse attention across the multiple-context KV cache.
//!
//! The assemble stage performs, per request (documents cached via the
//! prefill stage — the RAG premise):
//! 1. build the compressed cache (init+local blocks of every doc) and
//!    run the query's incremental prefill over it → `Q_que` (§3.1);
//! 2. personalize per document with the other docs' local Q caches
//!    (Eq. 1);
//! 3. analyze each doc's attention map (A.1) and score its blocks with
//!    Q̂ (host-side or the offloaded L1 `score_blocks` artifact);
//! 4. dynamic Top-P per stable layer (Eq. 2), averaged (Eq. 3), then
//!    cross-context filter (§3.2 last step);
//! 5. assemble the sparse buffer (init + selected + local per doc, in
//!    document order at *global* positions);
//! 6. recompute init/local + PauTa-outlier tokens with the Fig.-5
//!    layer-aligned plan; write back by overwrite or fusion (Eq. 4).
//!
//! The attend/decode stages (incremental query prefill over the new
//! cache + greedy streaming decode, §3.3) are driven by
//! [`super::pipeline::ServeSession`]. Every ablation axis of Table 4
//! (selection / personalized bias / recomputation, overwrite vs fusion)
//! is a [`SamKvConfig`] switch.

use std::sync::Arc;

use crate::attention::{analyze_doc, BlockAttention};
use crate::config::{ProfileConfig, SamKvConfig, UpdateStrategy};
use crate::kvcache::{AssembledContext, DocEntry, SlotKind};
use crate::model::{Buffer, Model};
use crate::sparse::{
    block_scores_host, build_recompute_plan, cross_filter,
    personalized_queries, topp_select, write_back,
};
use crate::tensor::Tensor;
use crate::workload::Sample;

use super::pipeline::{PlannedSpan, ReadyContext, ServePlan};
use super::ContextPolicy;

pub struct SamKvPolicy {
    pub cfg: SamKvConfig,
}

impl SamKvPolicy {
    pub fn new(cfg: SamKvConfig) -> SamKvPolicy {
        SamKvPolicy { cfg }
    }
}

/// Concatenate every document's init+local blocks into the compressed
/// cache fed to `query_embed` (§3.1 "composite Cache unit").
/// Returns `(comp_kv [L,2,H,Lc,Dh], comp_valid [Lc])`. Spans are
/// gathered straight out of the block pool; an evicted (unpinned)
/// span is an error.
pub fn build_compressed_cache(cfg: &ProfileConfig,
                              entries: &[Arc<DocEntry>])
                              -> crate::Result<(Tensor, Vec<f32>)> {
    let bs = cfg.block_size;
    let lc = cfg.comp_len;
    let mut comp = Tensor::zeros(&[cfg.n_layers, 2, cfg.n_heads, lc,
                                   cfg.head_dim]);
    let mut cursor = 0usize;
    for e in entries.iter() {
        let mut blocks: Vec<usize> = (0..cfg.init_blocks).collect();
        blocks.extend(
            cfg.blocks_per_doc - cfg.local_blocks..cfg.blocks_per_doc,
        );
        for b in blocks {
            for l in 0..cfg.n_layers {
                for c in 0..2 {
                    for h in 0..cfg.n_heads {
                        let dst = comp.slice_at_mut(&[l, c, h]);
                        let d = cfg.head_dim;
                        e.kv.copy_span(
                            l, c, h, b * bs, bs,
                            &mut dst[cursor * d..(cursor + bs) * d],
                        )?;
                    }
                }
            }
            cursor += bs;
        }
    }
    Ok((comp, vec![1.0; lc]))
}

impl ContextPolicy for SamKvPolicy {
    fn name(&self) -> String {
        match self.cfg.update {
            UpdateStrategy::Overwrite => "SamKV-overwrite".to_string(),
            UpdateStrategy::Fusion => "SamKV-fusion".to_string(),
        }
    }

    fn plan(&self, cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        let mut plan =
            ServePlan::docs_only(&self.name(), true, sample);
        plan.buffer = Buffer::Sparse;
        for doc in 0..sample.docs.len() {
            plan.fixed_spans.push(PlannedSpan {
                doc,
                start: 0,
                len: cfg.init_blocks * cfg.block_size,
                kind: SlotKind::Init,
            });
            plan.fixed_spans.push(PlannedSpan {
                doc,
                start: (cfg.blocks_per_doc - cfg.local_blocks)
                    * cfg.block_size,
                len: cfg.local_blocks * cfg.block_size,
                kind: SlotKind::Local,
            });
        }
        if self.cfg.selection {
            // Eq. 2/3 Top-P picks are dynamic; cap per doc
            plan.dynamic_blocks =
                sample.docs.len() * cfg.sel_cap_blocks;
        }
        if self.cfg.recompute {
            // init+local always recomputed; PauTa outliers add
            // dynamically (Fig. 5 planning)
            plan.planned_recompute_tokens = sample.docs.len()
                * cfg.fixed_blocks_per_doc()
                * cfg.block_size;
        }
        plan
    }

    fn assemble(&self, model: &Model, docs: &[Arc<DocEntry>],
                sample: &Sample) -> crate::Result<ReadyContext> {
        let cfg = model.cfg.clone();
        let k = &self.cfg;

        // --- §3.1: generic query vector over the compressed cache -----
        let (comp_kv, comp_valid) = build_compressed_cache(&cfg, docs)?;
        let q_pos: Vec<i32> = (0..cfg.query_len as i32)
            .map(|i| cfg.ctx_len as i32 + i)
            .collect();
        let qe = model.query_embed(&sample.query, comp_kv, &comp_valid,
                                   &q_pos)?;
        let q_locals: Vec<&Tensor> =
            docs.iter().map(|e| &e.q_local).collect();
        let q_hats =
            personalized_queries(&qe.q_que, &q_locals, k.pers_bias);

        // --- A.1 analytics + §3.2 selection per document ---------------
        let stable: Vec<usize> =
            (cfg.stable_layer_start()..cfg.n_layers).collect();
        let analyses: Vec<BlockAttention> = docs
            .iter()
            .map(|e| analyze_doc(&e.attn, &cfg, k.pauta_sigma))
            .collect();
        let picked_per_doc = if k.selection {
            let mut sels = Vec::with_capacity(docs.len());
            for (d, e) in docs.iter().enumerate() {
                // scoring walks every block anyway: one gather per doc
                let kv = e.kv.gather()?;
                let per_layer: Vec<Vec<f32>> = if k.offload_scoring {
                    let scores = model.score_blocks(
                        q_hats[d].clone(),
                        extract_k(&cfg, &kv),
                        &vec![1.0; cfg.doc_len],
                    )?;
                    stable
                        .iter()
                        .map(|&l| scores.slice_at(&[l]).to_vec())
                        .collect()
                } else {
                    stable
                        .iter()
                        .map(|&l| {
                            block_scores_host(&q_hats[d], &kv, &cfg, l)
                        })
                        .collect()
                };
                sels.push(topp_select(&cfg, &per_layer, &stable,
                                      &analyses[d]));
            }
            cross_filter(&cfg, &sels)
        } else {
            vec![Vec::new(); docs.len()]
        };

        // --- assemble the sparse buffer --------------------------------
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        for (d, e) in docs.iter().enumerate() {
            for b in 0..cfg.init_blocks {
                ctx.append_block(&cfg, e, d, b, SlotKind::Init)?;
            }
            for &b in &picked_per_doc[d] {
                ctx.append_block(&cfg, e, d, b, SlotKind::Selected)?;
            }
            for b in
                cfg.blocks_per_doc - cfg.local_blocks..cfg.blocks_per_doc
            {
                ctx.append_block(&cfg, e, d, b, SlotKind::Local)?;
            }
        }

        // --- §3.3 recomputation with Fig.-5 planning --------------------
        let mut recompute_ratio = 0.0;
        if k.recompute {
            let ba_refs: Vec<&BlockAttention> = analyses.iter().collect();
            let plan = build_recompute_plan(&cfg, &ctx, &ba_refs, true);
            recompute_ratio = plan.recompute_ratio;
            let kv_new = model.recompute(Buffer::Sparse, &ctx.tokens,
                                         &ctx.positions, &ctx.kv,
                                         plan.mask.clone(), &ctx.valid)?;
            let fused =
                write_back(&cfg, &ctx.kv, kv_new, &plan.mask, k.update);
            ctx.replace_kv(fused)?;
        }
        let mut ready = ReadyContext::new(&cfg, ctx, Buffer::Sparse);
        ready.recompute_ratio = recompute_ratio;
        Ok(ready)
    }
}

/// Pull the K half (`[L, H, Ld, Dh]`) out of a `[L, 2, H, Ld, Dh]`
/// cache for the offloaded scoring artifact.
fn extract_k(cfg: &ProfileConfig, kv: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[cfg.n_layers, cfg.n_heads, cfg.doc_len,
                                  cfg.head_dim]);
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            out.slice_at_mut(&[l, h])
                .copy_from_slice(kv.slice_at(&[l, 0, h]));
        }
    }
    out
}
