//! SamKV (§3): sparse attention across the multiple-context KV cache.
//!
//! Pipeline per request (documents assumed cached — the RAG premise):
//! 1. build the compressed cache (init+local blocks of every doc) and
//!    run the query's incremental prefill over it → `Q_que` (§3.1);
//! 2. personalize per document with the other docs' local Q caches
//!    (Eq. 1);
//! 3. analyze each doc's attention map (A.1) and score its blocks with
//!    Q̂ (host-side or the offloaded L1 `score_blocks` artifact);
//! 4. dynamic Top-P per stable layer (Eq. 2), averaged (Eq. 3), then
//!    cross-context filter (§3.2 last step);
//! 5. assemble the sparse buffer (init + selected + local per doc, in
//!    document order at *global* positions);
//! 6. recompute init/local + PauTa-outlier tokens with the Fig.-5
//!    layer-aligned plan; write back by overwrite or fusion (Eq. 4);
//! 7. incremental query prefill over the new cache + greedy decode.
//!
//! Every ablation axis of Table 4 (selection / personalized bias /
//! recomputation, overwrite vs fusion) is a [`SamKvConfig`] switch.

use std::time::Instant;

use crate::attention::{analyze_doc, BlockAttention};
use crate::config::{ProfileConfig, SamKvConfig, UpdateStrategy};
use crate::kvcache::{AssembledContext, CacheStore, DocEntry, SlotKind};
use crate::model::{Buffer, Model};
use crate::sparse::{
    block_scores_host, build_recompute_plan, cross_filter,
    personalized_queries, topp_select, write_back,
};
use crate::tensor::Tensor;
use crate::workload::Sample;

use super::common::query_and_decode;
use super::{ContextPolicy, PolicyOutput, RunStats};

pub struct SamKvPolicy {
    pub cfg: SamKvConfig,
}

impl SamKvPolicy {
    pub fn new(cfg: SamKvConfig) -> SamKvPolicy {
        SamKvPolicy { cfg }
    }
}

/// Concatenate every document's init+local blocks into the compressed
/// cache fed to `query_embed` (§3.1 "composite Cache unit").
/// Returns `(comp_kv [L,2,H,Lc,Dh], comp_valid [Lc])`.
pub fn build_compressed_cache(cfg: &ProfileConfig,
                              entries: &[std::rc::Rc<DocEntry>])
                              -> (Tensor, Vec<f32>) {
    let bs = cfg.block_size;
    let lc = cfg.comp_len;
    let mut comp = Tensor::zeros(&[cfg.n_layers, 2, cfg.n_heads, lc,
                                   cfg.head_dim]);
    let mut cursor = 0usize;
    for e in entries.iter() {
        let mut blocks: Vec<usize> = (0..cfg.init_blocks).collect();
        blocks.extend(
            cfg.blocks_per_doc - cfg.local_blocks..cfg.blocks_per_doc,
        );
        for b in blocks {
            for l in 0..cfg.n_layers {
                for c in 0..2 {
                    for h in 0..cfg.n_heads {
                        let src = e.kv.slice_at(&[l, c, h]);
                        let dst = comp.slice_at_mut(&[l, c, h]);
                        let d = cfg.head_dim;
                        dst[cursor * d..(cursor + bs) * d].copy_from_slice(
                            &src[b * bs * d..(b + 1) * bs * d],
                        );
                    }
                }
            }
            cursor += bs;
        }
    }
    (comp, vec![1.0; lc])
}

impl ContextPolicy for SamKvPolicy {
    fn name(&self) -> String {
        match self.cfg.update {
            UpdateStrategy::Overwrite => "SamKV-overwrite".to_string(),
            UpdateStrategy::Fusion => "SamKV-fusion".to_string(),
        }
    }

    fn run(&self, model: &Model, store: &mut CacheStore, sample: &Sample)
           -> crate::Result<PolicyOutput> {
        let cfg = model.cfg.clone();
        let k = &self.cfg;
        let mut warm = true;
        let entries: Vec<_> = sample
            .docs
            .iter()
            .map(|d| {
                let (e, hit) = store.get_or_prefill(model, d)?;
                warm &= hit;
                Ok(e)
            })
            .collect::<crate::Result<Vec<_>>>()?;

        let t0 = Instant::now();

        // --- §3.1: generic query vector over the compressed cache -----
        let (comp_kv, comp_valid) = build_compressed_cache(&cfg, &entries);
        let q_pos: Vec<i32> = (0..cfg.query_len as i32)
            .map(|i| cfg.ctx_len as i32 + i)
            .collect();
        let qe = model.query_embed(&sample.query, comp_kv, &comp_valid,
                                   &q_pos)?;
        let q_locals: Vec<&Tensor> =
            entries.iter().map(|e| &e.q_local).collect();
        let q_hats =
            personalized_queries(&qe.q_que, &q_locals, k.pers_bias);

        // --- A.1 analytics + §3.2 selection per document ---------------
        let stable: Vec<usize> =
            (cfg.stable_layer_start()..cfg.n_layers).collect();
        let analyses: Vec<BlockAttention> = entries
            .iter()
            .map(|e| analyze_doc(&e.attn, &cfg, k.pauta_sigma))
            .collect();
        let picked_per_doc = if k.selection {
            let mut sels = Vec::with_capacity(entries.len());
            for (d, e) in entries.iter().enumerate() {
                let per_layer: Vec<Vec<f32>> = if k.offload_scoring {
                    let scores = model.score_blocks(
                        q_hats[d].clone(),
                        extract_k(&cfg, &e.kv),
                        &vec![1.0; cfg.doc_len],
                    )?;
                    stable
                        .iter()
                        .map(|&l| scores.slice_at(&[l]).to_vec())
                        .collect()
                } else {
                    stable
                        .iter()
                        .map(|&l| {
                            block_scores_host(&q_hats[d], &e.kv, &cfg, l)
                        })
                        .collect()
                };
                sels.push(topp_select(&cfg, &per_layer, &stable,
                                      &analyses[d]));
            }
            cross_filter(&cfg, &sels)
        } else {
            vec![Vec::new(); entries.len()]
        };

        // --- assemble the sparse buffer --------------------------------
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        for (d, e) in entries.iter().enumerate() {
            for b in 0..cfg.init_blocks {
                ctx.append_block(&cfg, e, d, b, SlotKind::Init)?;
            }
            for &b in &picked_per_doc[d] {
                ctx.append_block(&cfg, e, d, b, SlotKind::Selected)?;
            }
            for b in
                cfg.blocks_per_doc - cfg.local_blocks..cfg.blocks_per_doc
            {
                ctx.append_block(&cfg, e, d, b, SlotKind::Local)?;
            }
        }
        let seq_ratio = ctx.seq_ratio(&cfg);
        let kv_bytes = ctx.kv_bytes(&cfg);

        // --- §3.3 recomputation with Fig.-5 planning --------------------
        let mut recompute_ratio = 0.0;
        if k.recompute {
            let ba_refs: Vec<&BlockAttention> = analyses.iter().collect();
            let plan = build_recompute_plan(&cfg, &ctx, &ba_refs, true);
            recompute_ratio = plan.recompute_ratio;
            let kv_new = model.recompute(Buffer::Sparse, &ctx.tokens,
                                         &ctx.positions, &ctx.kv,
                                         plan.mask.clone(), &ctx.valid)?;
            let fused =
                write_back(&cfg, &ctx.kv, kv_new, &plan.mask, k.update);
            ctx.replace_kv(fused)?;
        }
        let prep_ms = t0.elapsed().as_secs_f64() * 1e3;

        // --- §3.3 final incremental prefill + decode --------------------
        let td = Instant::now();
        let answer = query_and_decode(model, &cfg, &mut ctx,
                                      Buffer::Sparse, sample)?;
        let qa_ms = td.elapsed().as_secs_f64() * 1e3;
        let frac = cfg.query_len as f64
            / (cfg.query_len + answer.len().max(1)) as f64;

        Ok(PolicyOutput {
            answer,
            stats: RunStats {
                ttft_ms: prep_ms + qa_ms * frac,
                decode_ms: qa_ms * (1.0 - frac),
                seq_ratio,
                recompute_ratio,
                kv_bytes,
                cache_warm: warm,
            },
        })
    }
}

/// Pull the K half (`[L, H, Ld, Dh]`) out of a `[L, 2, H, Ld, Dh]`
/// cache for the offloaded scoring artifact.
fn extract_k(cfg: &ProfileConfig, kv: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[cfg.n_layers, cfg.n_heads, cfg.doc_len,
                                  cfg.head_dim]);
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            out.slice_at_mut(&[l, h])
                .copy_from_slice(kv.slice_at(&[l, 0, h]));
        }
    }
    out
}
