//! Shared serving steps: incremental query prefill (token-by-token
//! decode at global positions) over an assembled buffer.
//!
//! The greedy answer loop that used to live here (`query_and_decode`,
//! with its duplicated `answer_max` checks and potential dead final
//! decode step) moved into [`super::pipeline::ServeSession`], which
//! checks the bound in exactly one place and never runs a decode step
//! whose logits would be discarded.

use anyhow::Result;

use crate::config::ProfileConfig;
use crate::kvcache::AssembledContext;
use crate::model::{Buffer, Model};
use crate::workload::Sample;

/// Feed the user query incrementally over the assembled cache and
/// return the logits produced by its final token (the first answer
/// token's distribution).
///
/// The query occupies global positions `ctx_len .. ctx_len+Lq` (the
/// joint-training layout) regardless of how sparse the document KV is —
/// §3.3: "we re-perform an incremental prefill of the user query based
/// on KV_docs_new and then infer the answer".
pub fn prefill_query(model: &Model, cfg: &ProfileConfig,
                     ctx: &mut AssembledContext, buffer: Buffer,
                     query: &[i32]) -> Result<Vec<f32>> {
    let q0 = cfg.ctx_len as i32;
    let mut logits: Option<Vec<f32>> = None;
    for (i, &t) in query.iter().enumerate() {
        logits = Some(step(model, ctx, buffer, t, q0 + i as i32)?);
    }
    logits.ok_or_else(|| anyhow::anyhow!("empty query"))
}

/// One decode step: reserve a slot, run the artifact, mirror the KV.
/// Returns the step's logits.
pub fn step(model: &Model, ctx: &mut AssembledContext, buffer: Buffer,
            token: i32, position: i32) -> Result<Vec<f32>> {
    let slot = ctx.push_token(token, position)?;
    let out = model.decode(buffer, token, position, slot as i32,
                           &ctx.kv, &ctx.valid)?;
    ctx.write_token_kv(slot, &out.k_new, &out.v_new);
    Ok(out.logits)
}

/// Convenience for tests/benches: run a policy and return just the
/// answer.
pub fn answer_of(policy: &dyn super::ContextPolicy, model: &Model,
                 store: &mut crate::kvcache::EngineDocCache,
                 sample: &Sample) -> Result<Vec<i32>> {
    Ok(policy.run(model, store, sample)?.answer)
}
