//! Shared serving steps: incremental query prefill (token-by-token
//! decode at global positions) and greedy answer decoding over an
//! assembled buffer.

use anyhow::Result;

use crate::config::ProfileConfig;
use crate::kvcache::AssembledContext;
use crate::model::{Buffer, Model};
use crate::tokenizer as tok;
use crate::workload::Sample;

/// Feed the user query incrementally over the assembled cache, then
/// greedily decode up to `answer_max` tokens (stopping at EOS).
///
/// The query occupies global positions `ctx_len .. ctx_len+Lq` (the
/// joint-training layout) regardless of how sparse the document KV is —
/// §3.3: "we re-perform an incremental prefill of the user query based
/// on KV_docs_new and then infer the answer".
///
/// Returns `(answer, first_token_extra_ms)` where the extra time is the
/// query-prefill part of TTFT that this helper performed.
pub fn query_and_decode(model: &Model, cfg: &ProfileConfig,
                        ctx: &mut AssembledContext, buffer: Buffer,
                        sample: &Sample) -> Result<Vec<i32>> {
    let q0 = cfg.ctx_len as i32;
    let mut logits: Option<Vec<f32>> = None;
    for (i, &t) in sample.query.iter().enumerate() {
        let out = step(model, cfg, ctx, buffer, t, q0 + i as i32)?;
        logits = Some(out);
    }
    // greedy answer loop
    let mut answer = Vec::new();
    let mut pos = q0 + cfg.query_len as i32;
    let mut cur = Model::argmax(&logits.expect("query fed"));
    for _ in 0..cfg.answer_max {
        if cur == tok::EOS {
            break;
        }
        answer.push(cur);
        if answer.len() >= cfg.answer_max {
            break;
        }
        let out = step(model, cfg, ctx, buffer, cur, pos)?;
        cur = Model::argmax(&out);
        pos += 1;
    }
    Ok(answer)
}

/// One decode step: reserve a slot, run the artifact, mirror the KV.
fn step(model: &Model, _cfg: &ProfileConfig, ctx: &mut AssembledContext,
        buffer: Buffer, token: i32, position: i32) -> Result<Vec<f32>> {
    let slot = ctx.push_token(token, position)?;
    let out = model.decode(buffer, token, position, slot as i32,
                           &ctx.kv, &ctx.valid)?;
    ctx.write_token_kv(slot, &out.k_new, &out.v_new);
    Ok(out.logits)
}

/// Convenience for tests/benches: run a policy and return just the
/// answer.
pub fn answer_of(policy: &dyn super::ContextPolicy, model: &Model,
                 store: &mut crate::kvcache::CacheStore,
                 sample: &Sample) -> Result<Vec<i32>> {
    Ok(policy.run(model, store, sample)?.answer)
}
