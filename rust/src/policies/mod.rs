//! Context-cache policies: the paper's SamKV plus all evaluated
//! baselines, behind one staged [`ContextPolicy`] trait so the
//! coordinator, eval harness, and benches treat them uniformly.
//!
//! Every policy is served through the staged protocol defined in
//! [`pipeline`] — `plan` (pure, model-free) → `prefill_docs` (document
//! KV via the tiered [`EngineDocCache`]) → `assemble` (sparsify/recompute into a
//! decode-ready buffer) → `attend` (incremental query prefill) →
//! `decode_step` (one streamed token per call). Policies implement the
//! two policy-specific stages, [`ContextPolicy::plan`] and
//! [`ContextPolicy::assemble`]; [`pipeline::ServeSession`] drives the
//! rest, and the legacy blocking [`ContextPolicy::run`] survives only as
//! a default method delegating to the stages.
//!
//! | policy | sparse? | assemble stage does | KV loaded | paper row |
//! |--------|---------|---------------------|-----------|-----------|
//! | [`RecomputePolicy`] | n/a | full joint prefill (query included) | 100% | "Recompute" |
//! | [`ReusePolicy`] | no | verbatim concat of doc caches | 100% | "Reuse" |
//! | [`MultiInfLlmPolicy`] | yes (concat view) | InfLLM block retrieval | ~15% | "Multi-InfLLM" |
//! | [`CacheBlendPolicy`] | no | saliency-ranked ~15% token recompute | 100% | "CacheBlend" |
//! | [`EpicPolicy`] | no | AttnLink init+local recompute | 100% | "EPIC" |
//! | [`SamKvPolicy`] | yes (Eq. 1-3) | Top-P selection + Fig.-5 recompute | ~15% | "SamKV-overwrite/-fusion" |

pub mod cacheblend;
pub mod common;
pub mod epic;
pub mod multi_infllm;
pub mod pipeline;
pub mod recompute;
pub mod reuse;
pub mod samkv;

pub use cacheblend::CacheBlendPolicy;
pub use epic::EpicPolicy;
pub use multi_infllm::MultiInfLlmPolicy;
pub use pipeline::{
    serve_blocking, CollectSink, FnSink, FusedStep, NullSink, PlannedSpan,
    ReadyContext, ServePlan, ServeSession, SharedDoc, Stage, TokenSink,
};
pub use recompute::RecomputePolicy;
pub use reuse::ReusePolicy;
pub use samkv::SamKvPolicy;

use std::sync::Arc;

use crate::config::ProfileConfig;
use crate::kvcache::{DocEntry, EngineDocCache};
use crate::model::Model;
use crate::workload::Sample;

/// Measurements for one request (feeds Table 1, Fig. 1, Table 3/4).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Time to first generated token: assemble + attend + emitting the
    /// first token (the forward pass computing the *next* token's
    /// logits counts as decode). Excludes planning and document
    /// prefill, which are reported separately below (the paper's
    /// context-caching regime).
    pub ttft_ms: f64,
    /// Remaining decode time.
    pub decode_ms: f64,
    /// Time spent in the pure planning stage.
    pub plan_ms: f64,
    /// Time the request waited in the engine queue before planning
    /// started (submit → plan start). Zero on the blocking/eval path,
    /// where there is no queue.
    pub queue_wait_ms: f64,
    /// Time spent prefilling this request's document caches (zero when
    /// fully warm), including this request's share of batch-deduped
    /// shared prefills.
    pub doc_prefill_ms: f64,
    /// Fraction of the joint context KV held on the "device" during
    /// inference (Table 1 "sequence ratio").
    pub seq_ratio: f64,
    /// Fraction of context tokens recomputed (Table 1 "recomputation
    /// ratio").
    pub recompute_ratio: f64,
    /// Bytes of context KV loaded for this request (Fig. 1 circles).
    pub kv_bytes: usize,
    /// Whether every document KV was already cached (warm TTFT).
    pub cache_warm: bool,
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct PolicyOutput {
    pub answer: Vec<i32>,
    pub stats: RunStats,
}

/// A multi-context KV cache serving policy, expressed as the two
/// policy-specific stages of the [`pipeline`] protocol. Policies are
/// stateless tables of knobs and must be `Send + Sync`: the engine's
/// admission helper thread builds [`ServeSession`]s against them and
/// hands the sessions to the decode thread.
pub trait ContextPolicy: Send + Sync {
    /// Display name (matches the paper's tables).
    fn name(&self) -> String;

    /// Whether the policy consumes precomputed per-document caches
    /// (false only for full recomputation).
    fn uses_doc_cache(&self) -> bool {
        true
    }

    /// Stage 1 — pure, model-free planning: which document caches the
    /// request needs and which spans are statically known. Must not
    /// touch the model or the store.
    fn plan(&self, _cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        ServePlan::docs_only(&self.name(), self.uses_doc_cache(), sample)
    }

    /// Stage 3 — sparsify/select/recompute over the cached documents
    /// (in the order of `sample.docs`; empty when `uses_doc_cache()` is
    /// false) and return a decode-ready context.
    fn assemble(&self, model: &Model, docs: &[Arc<DocEntry>],
                sample: &Sample) -> crate::Result<ReadyContext>;

    /// Serve one request end to end: the legacy blocking entry point,
    /// implemented in terms of the stages (see
    /// [`pipeline::serve_blocking`]). Not meant to be overridden.
    fn run(&self, model: &Model, store: &mut EngineDocCache,
           sample: &Sample) -> crate::Result<PolicyOutput> {
        serve_blocking(self, model, store, sample)
    }
}

/// Table-3 row order of the paper's policies.
pub const POLICY_TABLE: [&str; 7] = [
    "Recompute",
    "Reuse",
    "Multi-InfLLM",
    "CacheBlend",
    "EPIC",
    "SamKV-overwrite",
    "SamKV-fusion",
];

/// Instantiate every paper policy (Table 3 row order). Construction
/// lives in [`policy_by_name`] so the two can't drift.
pub fn all_policies() -> Vec<Box<dyn ContextPolicy>> {
    POLICY_TABLE
        .iter()
        .map(|n| policy_by_name(n).expect("table policy constructs"))
        .collect()
}

/// Look a policy up by its table name, building only the requested one.
pub fn policy_by_name(name: &str) -> Option<Box<dyn ContextPolicy>> {
    use crate::config::{SamKvConfig, UpdateStrategy};
    Some(match name {
        "Recompute" => Box::new(RecomputePolicy),
        "Reuse" => Box::new(ReusePolicy),
        "Multi-InfLLM" => Box::new(MultiInfLlmPolicy),
        "CacheBlend" => Box::new(CacheBlendPolicy::default()),
        "EPIC" => Box::new(EpicPolicy::default()),
        "SamKV-overwrite" => Box::new(SamKvPolicy::new(SamKvConfig {
            update: UpdateStrategy::Overwrite,
            ..SamKvConfig::default()
        })),
        "SamKV-fusion" => {
            Box::new(SamKvPolicy::new(SamKvConfig::default()))
        }
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_by_name_matches_table_names() {
        for p in all_policies() {
            let name = p.name();
            let found = policy_by_name(&name)
                .unwrap_or_else(|| panic!("`{name}` not found"));
            assert_eq!(found.name(), name);
        }
        assert!(policy_by_name("NoSuchPolicy").is_none());
    }
}
