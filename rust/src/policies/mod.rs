//! Context-cache policies: the paper's SamKV plus all evaluated
//! baselines, behind one [`ContextPolicy`] trait so the coordinator,
//! eval harness, and benches treat them uniformly.
//!
//! | policy | sparse? | recompute? | KV loaded | paper row |
//! |--------|---------|------------|-----------|-----------|
//! | [`RecomputePolicy`] | n/a | full joint prefill | 100% | "Recompute" |
//! | [`ReusePolicy`] | no | none | 100% | "Reuse" |
//! | [`MultiInfLlmPolicy`] | yes (concat view) | none | ~15% | "Multi-InfLLM" |
//! | [`CacheBlendPolicy`] | no | ~15% of tokens | 100% | "CacheBlend" |
//! | [`EpicPolicy`] | no | init+local tokens | 100% | "EPIC" |
//! | [`SamKvPolicy`] | yes (Eq. 1-3) | sparse subset (Fig. 5) | ~15% | "SamKV-overwrite/-fusion" |

pub mod cacheblend;
pub mod common;
pub mod epic;
pub mod multi_infllm;
pub mod recompute;
pub mod reuse;
pub mod samkv;

pub use cacheblend::CacheBlendPolicy;
pub use epic::EpicPolicy;
pub use multi_infllm::MultiInfLlmPolicy;
pub use recompute::RecomputePolicy;
pub use reuse::ReusePolicy;
pub use samkv::SamKvPolicy;

use crate::kvcache::CacheStore;
use crate::model::Model;
use crate::workload::Sample;

/// Measurements for one request (feeds Table 1, Fig. 1, Table 3/4).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Time to first generated token, excluding cached doc prefill.
    pub ttft_ms: f64,
    /// Remaining decode time.
    pub decode_ms: f64,
    /// Fraction of the joint context KV held on the "device" during
    /// inference (Table 1 "sequence ratio").
    pub seq_ratio: f64,
    /// Fraction of context tokens recomputed (Table 1 "recomputation
    /// ratio").
    pub recompute_ratio: f64,
    /// Bytes of context KV loaded for this request (Fig. 1 circles).
    pub kv_bytes: usize,
    /// Whether every document KV was already cached (warm TTFT).
    pub cache_warm: bool,
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct PolicyOutput {
    pub answer: Vec<i32>,
    pub stats: RunStats,
}

/// A multi-context KV cache serving policy.
pub trait ContextPolicy {
    /// Display name (matches the paper's tables).
    fn name(&self) -> String;

    /// Whether the policy consumes precomputed per-document caches
    /// (false only for full recomputation).
    fn uses_doc_cache(&self) -> bool {
        true
    }

    /// Serve one request: produce the answer tokens + measurements.
    fn run(&self, model: &Model, store: &mut CacheStore, sample: &Sample)
           -> crate::Result<PolicyOutput>;
}

/// Instantiate every paper policy (Table 3 row order).
pub fn all_policies() -> Vec<Box<dyn ContextPolicy>> {
    use crate::config::{SamKvConfig, UpdateStrategy};
    vec![
        Box::new(RecomputePolicy),
        Box::new(ReusePolicy),
        Box::new(MultiInfLlmPolicy),
        Box::new(CacheBlendPolicy::default()),
        Box::new(EpicPolicy::default()),
        Box::new(SamKvPolicy::new(SamKvConfig {
            update: UpdateStrategy::Overwrite,
            ..SamKvConfig::default()
        })),
        Box::new(SamKvPolicy::new(SamKvConfig::default())), // fusion
    ]
}

/// Look a policy up by its table name.
pub fn policy_by_name(name: &str) -> Option<Box<dyn ContextPolicy>> {
    all_policies().into_iter().find(|p| p.name() == name)
}
