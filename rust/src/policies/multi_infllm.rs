//! "Multi-InfLLM" baseline: treat the concatenated per-document caches
//! as ONE single-context cache and apply InfLLM-style sparsification —
//! initial blocks + local window + query-similarity-retrieved middle
//! blocks. No recomputation, no cross-context awareness (the paper's
//! §4.1 adaptation of InfLLM to the multi-context setting).

use std::sync::Arc;

use crate::config::ProfileConfig;
use crate::kvcache::{AssembledContext, DocEntry, SlotKind};
use crate::model::{Buffer, Model};
use crate::sparse::block_scores_host;
use crate::workload::Sample;

use super::pipeline::{PlannedSpan, ReadyContext, ServePlan};
use super::ContextPolicy;

pub struct MultiInfLlmPolicy;

impl ContextPolicy for MultiInfLlmPolicy {
    fn name(&self) -> String {
        "Multi-InfLLM".to_string()
    }

    fn plan(&self, cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        let mut plan = ServePlan::docs_only("Multi-InfLLM", true, sample);
        plan.buffer = Buffer::Sparse;
        // concatenated view: init block of the first doc, local window
        // of the last doc; everything else is retrieved dynamically
        if !sample.docs.is_empty() {
            plan.fixed_spans.push(PlannedSpan {
                doc: 0,
                start: 0,
                len: cfg.block_size,
                kind: SlotKind::Init,
            });
            plan.fixed_spans.push(PlannedSpan {
                doc: sample.docs.len() - 1,
                start: (cfg.blocks_per_doc - cfg.local_blocks)
                    * cfg.block_size,
                len: cfg.local_blocks * cfg.block_size,
                kind: SlotKind::Local,
            });
        }
        let total_budget = cfg.sparse_kv_len / cfg.block_size;
        plan.dynamic_blocks =
            total_budget.saturating_sub(1 + cfg.local_blocks);
        plan
    }

    fn assemble(&self, model: &Model, docs: &[Arc<DocEntry>],
                sample: &Sample) -> crate::Result<ReadyContext> {
        let cfg = model.cfg.clone();
        // generic retrieval vector: incremental query prefill over the
        // concatenated init+local compressed cache (same machinery the
        // paper grants every sparse method)
        let (comp_kv, comp_valid) =
            super::samkv::build_compressed_cache(&cfg, docs)?;
        let q_pos: Vec<i32> = (0..cfg.query_len as i32)
            .map(|i| cfg.ctx_len as i32 + i)
            .collect();
        let qe = model.query_embed(&sample.query, comp_kv, &comp_valid,
                                   &q_pos)?;

        // concatenated-view selection: first block of doc 0 (init),
        // last blocks of the last doc (local), then the best-scoring
        // middle blocks anywhere, up to the sparse budget
        let total_budget = cfg.sparse_kv_len / cfg.block_size;
        let mut picks: Vec<(usize, usize, SlotKind)> = Vec::new();
        picks.push((0, 0, SlotKind::Init));
        for b in 0..cfg.local_blocks {
            picks.push((cfg.n_docs - 1,
                        cfg.blocks_per_doc - cfg.local_blocks + b,
                        SlotKind::Local));
        }
        // score every remaining block of the concatenated cache
        let stable = cfg.stable_layer_start();
        let mut scored: Vec<(f32, usize, usize)> = Vec::new();
        for (d, e) in docs.iter().enumerate() {
            let mut acc = vec![0f32; cfg.blocks_per_doc];
            // scoring reads every block: one pool gather per doc
            let kv = e.kv.gather()?;
            for l in stable..cfg.n_layers {
                let s = block_scores_host(&qe.q_que, &kv, &cfg, l);
                for (a, v) in acc.iter_mut().zip(s) {
                    *a += v;
                }
            }
            for (b, &v) in acc.iter().enumerate() {
                if !picks.iter().any(|&(pd, pb, _)| pd == d && pb == b) {
                    scored.push((v, d, b));
                }
            }
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, d, b) in scored.iter().take(total_budget - picks.len()) {
            picks.push((d, b, SlotKind::Selected));
        }
        picks.sort_by_key(|&(d, b, _)| (d, b));

        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        for &(d, b, kind) in &picks {
            ctx.append_block(&cfg, &docs[d], d, b, kind)?;
        }
        Ok(ReadyContext::new(&cfg, ctx, Buffer::Sparse))
    }
}
