//! "Multi-InfLLM" baseline: treat the concatenated per-document caches
//! as ONE single-context cache and apply InfLLM-style sparsification —
//! initial blocks + local window + query-similarity-retrieved middle
//! blocks. No recomputation, no cross-context awareness (the paper's
//! §4.1 adaptation of InfLLM to the multi-context setting).

use std::time::Instant;

use crate::kvcache::{AssembledContext, CacheStore, SlotKind};
use crate::model::{Buffer, Model};
use crate::sparse::block_scores_host;
use crate::workload::Sample;

use super::common::query_and_decode;
use super::{ContextPolicy, PolicyOutput, RunStats};

pub struct MultiInfLlmPolicy;

impl ContextPolicy for MultiInfLlmPolicy {
    fn name(&self) -> String {
        "Multi-InfLLM".to_string()
    }

    fn run(&self, model: &Model, store: &mut CacheStore, sample: &Sample)
           -> crate::Result<PolicyOutput> {
        let cfg = model.cfg.clone();
        let mut warm = true;
        let entries: Vec<_> = sample
            .docs
            .iter()
            .map(|d| {
                let (e, hit) = store.get_or_prefill(model, d)?;
                warm &= hit;
                Ok(e)
            })
            .collect::<crate::Result<Vec<_>>>()?;

        let t0 = Instant::now();
        // generic retrieval vector: incremental query prefill over the
        // concatenated init+local compressed cache (same machinery the
        // paper grants every sparse method)
        let (comp_kv, comp_valid) =
            super::samkv::build_compressed_cache(&cfg, &entries);
        let q_pos: Vec<i32> = (0..cfg.query_len as i32)
            .map(|i| cfg.ctx_len as i32 + i)
            .collect();
        let qe = model.query_embed(&sample.query, comp_kv, &comp_valid,
                                   &q_pos)?;

        // concatenated-view selection: first block of doc 0 (init),
        // last blocks of the last doc (local), then the best-scoring
        // middle blocks anywhere, up to the sparse budget
        let total_budget = cfg.sparse_kv_len / cfg.block_size;
        let mut picks: Vec<(usize, usize, SlotKind)> = Vec::new();
        picks.push((0, 0, SlotKind::Init));
        for b in 0..cfg.local_blocks {
            picks.push((cfg.n_docs - 1,
                        cfg.blocks_per_doc - cfg.local_blocks + b,
                        SlotKind::Local));
        }
        // score every remaining block of the concatenated cache
        let stable = cfg.stable_layer_start();
        let mut scored: Vec<(f32, usize, usize)> = Vec::new();
        for (d, e) in entries.iter().enumerate() {
            let mut acc = vec![0f32; cfg.blocks_per_doc];
            for l in stable..cfg.n_layers {
                let s = block_scores_host(&qe.q_que, &e.kv, &cfg, l);
                for (a, v) in acc.iter_mut().zip(s) {
                    *a += v;
                }
            }
            for (b, &v) in acc.iter().enumerate() {
                if !picks.iter().any(|&(pd, pb, _)| pd == d && pb == b) {
                    scored.push((v, d, b));
                }
            }
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, d, b) in scored.iter().take(total_budget - picks.len()) {
            picks.push((d, b, SlotKind::Selected));
        }
        picks.sort_by_key(|&(d, b, _)| (d, b));

        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        for &(d, b, kind) in &picks {
            ctx.append_block(&cfg, &entries[d], d, b, kind)?;
        }
        let seq_ratio = ctx.seq_ratio(&cfg);
        let kv_bytes = ctx.kv_bytes(&cfg);
        let prep_ms = t0.elapsed().as_secs_f64() * 1e3;

        let td = Instant::now();
        let answer = query_and_decode(model, &cfg, &mut ctx,
                                      Buffer::Sparse, sample)?;
        let qa_ms = td.elapsed().as_secs_f64() * 1e3;
        let frac = cfg.query_len as f64
            / (cfg.query_len + answer.len().max(1)) as f64;

        Ok(PolicyOutput {
            answer,
            stats: RunStats {
                ttft_ms: prep_ms + qa_ms * frac,
                decode_ms: qa_ms * (1.0 - frac),
                seq_ratio,
                recompute_ratio: 0.0,
                kv_bytes,
                cache_warm: warm,
            },
        })
    }
}
