//! "Reuse" baseline: concatenate the independently-prefilled document
//! caches verbatim — no recomputation, no sparsification. Fastest TTFT,
//! collapses on position-critical queries (the paper's motivating
//! failure: missing cross-attention + RoPE position collisions).

use std::time::Instant;

use crate::kvcache::{AssembledContext, CacheStore};
use crate::model::{Buffer, Model};
use crate::workload::Sample;

use super::common::query_and_decode;
use super::{ContextPolicy, PolicyOutput, RunStats};

pub struct ReusePolicy;

impl ContextPolicy for ReusePolicy {
    fn name(&self) -> String {
        "Reuse".to_string()
    }

    fn run(&self, model: &Model, store: &mut CacheStore, sample: &Sample)
           -> crate::Result<PolicyOutput> {
        let cfg = model.cfg.clone();
        let mut warm = true;
        let entries: Vec<_> = sample
            .docs
            .iter()
            .map(|d| {
                let (e, hit) = store.get_or_prefill(model, d)?;
                warm &= hit;
                Ok(e)
            })
            .collect::<crate::Result<Vec<_>>>()?;

        let t0 = Instant::now();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        for (d, e) in entries.iter().enumerate() {
            ctx.append_doc(&cfg, e, d)?;
        }
        let seq_ratio = ctx.seq_ratio(&cfg);
        let kv_bytes = ctx.kv_bytes(&cfg);
        let ttft_partial = t0.elapsed().as_secs_f64() * 1e3;

        let td = Instant::now();
        let answer = query_and_decode(model, &cfg, &mut ctx, Buffer::Full,
                                      sample)?;
        let qa_ms = td.elapsed().as_secs_f64() * 1e3;
        // TTFT = assembly + query prefill (5 decode steps) + 1st token;
        // approximate the query part as Lq/(Lq+answer) of the loop time
        let frac = cfg.query_len as f64
            / (cfg.query_len + answer.len().max(1)) as f64;

        Ok(PolicyOutput {
            answer,
            stats: RunStats {
                ttft_ms: ttft_partial + qa_ms * frac,
                decode_ms: qa_ms * (1.0 - frac),
                seq_ratio,
                recompute_ratio: 0.0,
                kv_bytes,
                cache_warm: warm,
            },
        })
    }
}
