//! "Reuse" baseline: concatenate the independently-prefilled document
//! caches verbatim — no recomputation, no sparsification. Fastest TTFT,
//! collapses on position-critical queries (the paper's motivating
//! failure: missing cross-attention + RoPE position collisions).

use std::sync::Arc;

use crate::config::ProfileConfig;
use crate::kvcache::{AssembledContext, DocEntry};
use crate::model::{Buffer, Model};
use crate::workload::Sample;

use super::pipeline::{ReadyContext, ServePlan};
use super::ContextPolicy;

pub struct ReusePolicy;

impl ContextPolicy for ReusePolicy {
    fn name(&self) -> String {
        "Reuse".to_string()
    }

    fn plan(&self, cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        ServePlan::full_docs("Reuse", cfg, sample)
    }

    fn assemble(&self, model: &Model, docs: &[Arc<DocEntry>],
                _sample: &Sample) -> crate::Result<ReadyContext> {
        let cfg = model.cfg.clone();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        for (d, e) in docs.iter().enumerate() {
            ctx.append_doc(&cfg, e, d)?;
        }
        Ok(ReadyContext::new(&cfg, ctx, Buffer::Full))
    }
}
