//! "CacheBlend" baseline (Yao et al. 2024): load the FULL concatenated
//! multi-context cache, then selectively recompute ~15% of tokens to
//! restore cross-attention, with the recomputed set shrinking in deeper
//! layers. Sequence ratio stays 100% — the memory cost SamKV removes.
//!
//! Token selection substitutes attention-received saliency (from the
//! stored per-document attention maps) for CacheBlend's KV-deviation
//! criterion — the deviation signal needs intermediate activations our
//! AOT interface doesn't expose; saliency preserves the structural
//! behaviour (high-impact tokens get refreshed first). Documented in
//! DESIGN.md §2.

use std::sync::Arc;

use crate::config::ProfileConfig;
use crate::kvcache::{AssembledContext, DocEntry};
use crate::model::{Buffer, Model};
use crate::tensor::Tensor;
use crate::workload::Sample;

use super::pipeline::{ReadyContext, ServePlan};
use super::ContextPolicy;

pub struct CacheBlendPolicy {
    /// Base fraction of context tokens recomputed at layer 0.
    pub recompute_ratio: f64,
    /// Per-layer shrink factor ("the scope of updates decreasing
    /// progressively across layers").
    pub layer_decay: f64,
}

impl Default for CacheBlendPolicy {
    fn default() -> Self {
        CacheBlendPolicy { recompute_ratio: 0.16, layer_decay: 0.85 }
    }
}

/// Attention-received saliency per token of one document (mean over
/// layers/heads of attention from subsequent queries).
pub fn token_saliency(cfg: &crate::config::ProfileConfig,
                      entry: &DocEntry) -> Vec<f32> {
    let (nl, nh, ld) = (cfg.n_layers, cfg.n_heads, cfg.doc_len);
    let mut s = vec![0f32; ld];
    for t in 0..ld {
        let nq = ld - t - 1;
        if nq == 0 {
            continue;
        }
        let mut acc = 0f32;
        for l in 0..nl {
            for h in 0..nh {
                for q in (t + 1)..ld {
                    acc += entry.attn.at(&[l, h, q, t]);
                }
            }
        }
        s[t] = acc / (nl * nh * nq) as f32;
    }
    s
}

impl ContextPolicy for CacheBlendPolicy {
    fn name(&self) -> String {
        "CacheBlend".to_string()
    }

    fn plan(&self, cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        let mut plan = ServePlan::full_docs("CacheBlend", cfg, sample);
        // layer-0 saliency budget per doc (which tokens is dynamic)
        let keep = (self.recompute_ratio * cfg.doc_len as f64).ceil()
            as usize;
        plan.planned_recompute_tokens = sample.docs.len() * keep;
        plan
    }

    fn assemble(&self, model: &Model, docs: &[Arc<DocEntry>],
                _sample: &Sample) -> crate::Result<ReadyContext> {
        let cfg = model.cfg.clone();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        for (d, e) in docs.iter().enumerate() {
            ctx.append_doc(&cfg, e, d)?;
        }
        // layer-shrinking saliency mask
        let mut mask = Tensor::zeros(&[cfg.n_layers, cfg.full_len]);
        let mut union = vec![false; cfg.full_len];
        for (d, e) in docs.iter().enumerate() {
            let sal = token_saliency(&cfg, e);
            let mut order: Vec<usize> = (0..cfg.doc_len).collect();
            order.sort_by(|&a, &b| sal[b].partial_cmp(&sal[a]).unwrap());
            for l in 0..cfg.n_layers {
                let keep = ((self.recompute_ratio
                    * self.layer_decay.powi(l as i32))
                    * cfg.doc_len as f64)
                    .ceil() as usize;
                let row = mask.slice_at_mut(&[l]);
                row[cfg.doc_offset(d)] = 1.0; // BOS always
                union[cfg.doc_offset(d)] = true;
                for &t in order.iter().take(keep) {
                    row[cfg.doc_offset(d) + t] = 1.0;
                    union[cfg.doc_offset(d) + t] = true;
                }
            }
        }
        let recomputed = union.iter().filter(|&&u| u).count();

        let kv_new = model.recompute(Buffer::Full, &ctx.tokens,
                                     &ctx.positions, &ctx.kv, mask,
                                     &ctx.valid)?;
        ctx.replace_kv(kv_new)?;
        let mut ready = ReadyContext::new(&cfg, ctx, Buffer::Full);
        ready.recompute_ratio = recomputed as f64 / cfg.ctx_len as f64;
        Ok(ready)
    }
}
