//! "EPIC" baseline (Hu et al. 2024): position-independent context
//! caching that recomputes only the *initial* tokens of every chunk
//! plus a local window (AttnLink), over the full loaded cache.

use std::sync::Arc;

use crate::config::ProfileConfig;
use crate::kvcache::{AssembledContext, DocEntry};
use crate::model::{Buffer, Model};
use crate::tensor::Tensor;
use crate::workload::Sample;

use super::pipeline::{ReadyContext, ServePlan};
use super::ContextPolicy;

pub struct EpicPolicy {
    /// Fraction of each document recomputed at its head.
    pub init_frac: f64,
    /// Fraction recomputed at its tail (local window).
    pub local_frac: f64,
}

impl Default for EpicPolicy {
    fn default() -> Self {
        // ~14% of each document, split head-heavy like EPIC's AttnLink
        EpicPolicy { init_frac: 0.09, local_frac: 0.05 }
    }
}

impl EpicPolicy {
    /// (init, local) recompute window sizes in tokens per document.
    fn windows(&self, cfg: &ProfileConfig) -> (usize, usize) {
        let init = ((self.init_frac * cfg.doc_len as f64).ceil() as usize)
            .max(1)
            .min(cfg.doc_len);
        let local = ((self.local_frac * cfg.doc_len as f64).ceil()
            as usize)
            .max(1)
            .min(cfg.doc_len - init);
        (init, local)
    }
}

impl ContextPolicy for EpicPolicy {
    fn name(&self) -> String {
        "EPIC".to_string()
    }

    fn plan(&self, cfg: &ProfileConfig, sample: &Sample) -> ServePlan {
        let mut plan = ServePlan::full_docs("EPIC", cfg, sample);
        // AttnLink windows are statically known: the whole recompute
        // set is fixed before any attention is seen
        let (init, local) = self.windows(cfg);
        plan.planned_recompute_tokens = sample.docs.len() * (init + local);
        plan
    }

    fn assemble(&self, model: &Model, docs: &[Arc<DocEntry>],
                _sample: &Sample) -> crate::Result<ReadyContext> {
        let cfg = model.cfg.clone();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        for (d, e) in docs.iter().enumerate() {
            ctx.append_doc(&cfg, e, d)?;
        }
        let (init, local) = self.windows(&cfg);
        let mut mask = Tensor::zeros(&[cfg.n_layers, cfg.full_len]);
        for d in 0..cfg.n_docs {
            let off = cfg.doc_offset(d);
            for l in 0..cfg.n_layers {
                let row = mask.slice_at_mut(&[l]);
                for t in 0..init {
                    row[off + t] = 1.0;
                }
                for t in (cfg.doc_len - local)..cfg.doc_len {
                    row[off + t] = 1.0;
                }
            }
        }
        let recomputed = cfg.n_docs * (init + local);

        let kv_new = model.recompute(Buffer::Full, &ctx.tokens,
                                     &ctx.positions, &ctx.kv, mask,
                                     &ctx.valid)?;
        ctx.replace_kv(kv_new)?;
        let mut ready = ReadyContext::new(&cfg, ctx, Buffer::Full);
        ready.recompute_ratio = recomputed as f64 / cfg.ctx_len as f64;
        Ok(ready)
    }
}
