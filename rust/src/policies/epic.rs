//! "EPIC" baseline (Hu et al. 2024): position-independent context
//! caching that recomputes only the *initial* tokens of every chunk
//! plus a local window (AttnLink), over the full loaded cache.

use std::time::Instant;

use crate::kvcache::{AssembledContext, CacheStore};
use crate::model::{Buffer, Model};
use crate::tensor::Tensor;
use crate::workload::Sample;

use super::common::query_and_decode;
use super::{ContextPolicy, PolicyOutput, RunStats};

pub struct EpicPolicy {
    /// Fraction of each document recomputed at its head.
    pub init_frac: f64,
    /// Fraction recomputed at its tail (local window).
    pub local_frac: f64,
}

impl Default for EpicPolicy {
    fn default() -> Self {
        // ~14% of each document, split head-heavy like EPIC's AttnLink
        EpicPolicy { init_frac: 0.09, local_frac: 0.05 }
    }
}

impl ContextPolicy for EpicPolicy {
    fn name(&self) -> String {
        "EPIC".to_string()
    }

    fn run(&self, model: &Model, store: &mut CacheStore, sample: &Sample)
           -> crate::Result<PolicyOutput> {
        let cfg = model.cfg.clone();
        let mut warm = true;
        let entries: Vec<_> = sample
            .docs
            .iter()
            .map(|d| {
                let (e, hit) = store.get_or_prefill(model, d)?;
                warm &= hit;
                Ok(e)
            })
            .collect::<crate::Result<Vec<_>>>()?;

        let t0 = Instant::now();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        for (d, e) in entries.iter().enumerate() {
            ctx.append_doc(&cfg, e, d)?;
        }
        let init = ((self.init_frac * cfg.doc_len as f64).ceil() as usize)
            .max(1)
            .min(cfg.doc_len);
        let local = ((self.local_frac * cfg.doc_len as f64).ceil()
            as usize)
            .max(1)
            .min(cfg.doc_len - init);
        let mut mask = Tensor::zeros(&[cfg.n_layers, cfg.full_len]);
        for d in 0..cfg.n_docs {
            let off = cfg.doc_offset(d);
            for l in 0..cfg.n_layers {
                let row = mask.slice_at_mut(&[l]);
                for t in 0..init {
                    row[off + t] = 1.0;
                }
                for t in (cfg.doc_len - local)..cfg.doc_len {
                    row[off + t] = 1.0;
                }
            }
        }
        let recomputed = cfg.n_docs * (init + local);

        let kv_new = model.recompute(Buffer::Full, &ctx.tokens,
                                     &ctx.positions, &ctx.kv, mask,
                                     &ctx.valid)?;
        ctx.replace_kv(kv_new)?;
        let seq_ratio = ctx.seq_ratio(&cfg);
        let kv_bytes = ctx.kv_bytes(&cfg);
        let prep_ms = t0.elapsed().as_secs_f64() * 1e3;

        let td = Instant::now();
        let answer = query_and_decode(model, &cfg, &mut ctx, Buffer::Full,
                                      sample)?;
        let qa_ms = td.elapsed().as_secs_f64() * 1e3;
        let frac = cfg.query_len as f64
            / (cfg.query_len + answer.len().max(1)) as f64;

        Ok(PolicyOutput {
            answer,
            stats: RunStats {
                ttft_ms: prep_ms + qa_ms * frac,
                decode_ms: qa_ms * (1.0 - frac),
                seq_ratio,
                recompute_ratio: recomputed as f64 / cfg.ctx_len as f64,
                kv_bytes,
                cache_warm: warm,
            },
        })
    }
}
