//! Staged serving protocol: `plan → prefill_docs → assemble → attend →
//! decode_step*`, replacing the old monolithic `ContextPolicy::run()`.
//!
//! # Stage lifecycle
//!
//! A [`ServeSession`] drives one request through five explicit stages
//! (tracked by [`Stage`]; each method enforces its precondition and
//! advances the machine):
//!
//! 1. **plan** ([`ServeSession::new`]) — pure and model-free: the
//!    policy's [`super::ContextPolicy::plan`] computes a [`ServePlan`]
//!    describing which document caches the request needs (content
//!    hashes), which buffer geometry it will occupy, and which token
//!    spans are statically known to be kept or recomputed. Because no
//!    device or model state is touched, the engine can plan a whole
//!    batch up front and schedule shared work across requests — see
//!    [`dedup_doc_plans`].
//! 2. **prefill_docs** ([`ServeSession::prefill_docs`]) — pin the
//!    planned doc hashes (a [`PinGuard`] held until the session ends,
//!    so tier eviction can never race the stages below), then ensure
//!    every planned document KV exists in the tiered cache (resident →
//!    shared host tier → prefill-and-publish; see [`crate::kvcache`]).
//!    The engine may instead prefill shared documents once per batch
//!    and report the attributable cost via
//!    [`ServeSession::credit_shared_prefill`]; the per-session call then
//!    only performs (cheap) cache hits.
//! 3. **assemble** ([`ServeSession::assemble`]) — the policy sparsifies,
//!    selects, and recomputes over the cached documents and returns a
//!    decode-ready [`ReadyContext`] (Eq. 1-3 selection + §3.3 local
//!    recomputation for SamKV; saliency/AttnLink recomputation for the
//!    baselines; the full joint prefill for Recompute).
//! 4. **attend** ([`ServeSession::attend`]) — incremental prefill of the
//!    user query over the assembled cache (§3.3), producing the logits
//!    of the first answer token. Policies whose assemble already fed the
//!    query (Recompute's joint prefill) skip the extra work.
//! 5. **decode_step** ([`ServeSession::decode_step`]) — emit exactly one
//!    answer token per call (greedy argmax), streaming it through the
//!    caller's [`TokenSink`], until EOS or `answer_max`. The bound is
//!    checked in one place and no decode step ever runs whose logits
//!    would be discarded. [`ServeSession::finish`] then yields the
//!    final [`super::PolicyOutput`] with per-stage timings
//!    (`plan_ms`, `doc_prefill_ms`, `queue_wait_ms` split out of
//!    `ttft_ms`).
//!
//! # Fused decode rounds
//!
//! `decode_step` is also available split in two halves so an engine
//! can run one fused model dispatch per round over many sessions —
//! [`crate::model::Model::decode_batch`] packs the round's same-buffer
//! sessions into the lane-padded `decode_{sparse,full}_batched`
//! artifacts, a single XLA execution per lane chunk:
//! [`ServeSession::decode_step_begin`] consumes the pending logits,
//! emits at most one token through the sink, and — when the session
//! wants another token — reserves its KV slot and returns a
//! [`FusedStep`] describing the forward pass it needs;
//! [`ServeSession::decode_step_complete`] then accepts the externally
//! computed [`DecodeOut`] and folds it back into the session (KV
//! mirror, next logits, timing), so all session state and timing
//! accounting stays here regardless of who ran the model.
//! `decode_step` itself is implemented over the same two halves with a
//! single-request dispatch, so the fused and per-session paths cannot
//! drift.
//!
//! # `TokenSink` contract
//!
//! [`TokenSink::on_token`] is invoked **synchronously, exactly once per
//! generated answer token, in generation order**, before
//! `decode_step` returns that token. EOS is never delivered to the
//! sink; the tokens observed by the sink are exactly the final
//! `PolicyOutput::answer`. Sinks must not block for long (they run on
//! the engine thread) and must not call back into the session.
//! [`NullSink`] ignores tokens (blocking callers that only want the
//! final answer), [`CollectSink`] accumulates them, and [`FnSink`]
//! adapts a closure (the engine uses it to forward tokens onto the
//! response channel as they are produced).
//!
//! The legacy entry point survives as the default
//! `ContextPolicy::run()`, implemented by [`serve_blocking`] in terms of
//! the stages, so callers that don't care about staging or streaming
//! migrate without change.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ProfileConfig;
use crate::kvcache::store::doc_hash;
use crate::kvcache::{
    AssembledContext, DocEntry, EngineDocCache, PinGuard, SlotKind,
};
use crate::model::{Buffer, DecodeOut, Model};
use crate::tensor::Tensor;
use crate::tokenizer as tok;
use crate::workload::Sample;

use super::common;
use super::{ContextPolicy, PolicyOutput, RunStats};

/// Streaming consumer of decoded tokens (see the module docs for the
/// delivery contract).
pub trait TokenSink {
    fn on_token(&mut self, token: i32);
}

/// Ignores tokens — for blocking callers that read the final answer.
#[derive(Debug, Default)]
pub struct NullSink;

impl TokenSink for NullSink {
    fn on_token(&mut self, _token: i32) {}
}

/// Collects tokens into a vector.
#[derive(Debug, Default)]
pub struct CollectSink(pub Vec<i32>);

impl TokenSink for CollectSink {
    fn on_token(&mut self, token: i32) {
        self.0.push(token);
    }
}

/// Adapts a closure into a sink.
pub struct FnSink<F: FnMut(i32)>(pub F);

impl<F: FnMut(i32)> TokenSink for FnSink<F> {
    fn on_token(&mut self, token: i32) {
        (self.0)(token);
    }
}

/// A token span of one document with a planned role in the buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedSpan {
    pub doc: usize,
    /// Token offset within the document.
    pub start: usize,
    pub len: usize,
    pub kind: SlotKind,
}

/// Pure, model-free plan for serving one request: what the request
/// needs before it can assemble, and what it will statically do.
/// Computable per request without holding the device, so the engine can
/// plan a whole batch and dedup shared document prefills.
#[derive(Debug, Clone)]
pub struct ServePlan {
    /// Policy table name.
    pub policy: String,
    /// False only for full recomputation (no document caches consumed).
    pub needs_doc_cache: bool,
    /// Content hashes of the per-document KV caches this request needs,
    /// in document order (empty when `needs_doc_cache` is false).
    pub doc_hashes: Vec<u64>,
    /// Buffer geometry the assembled context will occupy.
    pub buffer: Buffer,
    /// Statically known resident spans (init/local/full blocks).
    /// Dynamically selected spans are counted in `dynamic_blocks`.
    pub fixed_spans: Vec<PlannedSpan>,
    /// Upper bound on blocks chosen at assemble time (Eq. 2/3 Top-P,
    /// InfLLM retrieval) — unknown until attention scores exist.
    pub dynamic_blocks: usize,
    /// Statically planned recomputation size in tokens (PauTa outliers
    /// and saliency picks add dynamically at assemble time).
    pub planned_recompute_tokens: usize,
}

impl ServePlan {
    /// Minimal plan: the request needs its documents cached, nothing
    /// more is statically known.
    pub fn docs_only(policy: &str, needs_doc_cache: bool, sample: &Sample)
                     -> ServePlan {
        ServePlan {
            policy: policy.to_string(),
            needs_doc_cache,
            doc_hashes: if needs_doc_cache {
                sample.docs.iter().map(|d| doc_hash(d)).collect()
            } else {
                Vec::new()
            },
            buffer: Buffer::Full,
            fixed_spans: Vec::new(),
            dynamic_blocks: 0,
            planned_recompute_tokens: 0,
        }
    }

    /// Plan for policies that keep every document fully resident in
    /// the full buffer (Reuse / CacheBlend / EPIC): [`Self::docs_only`]
    /// plus one `Full` span per document.
    pub fn full_docs(policy: &str, cfg: &ProfileConfig, sample: &Sample)
                     -> ServePlan {
        let mut plan = ServePlan::docs_only(policy, true, sample);
        plan.buffer = Buffer::Full;
        for doc in 0..sample.docs.len() {
            plan.fixed_spans.push(PlannedSpan {
                doc,
                start: 0,
                len: cfg.doc_len,
                kind: SlotKind::Full,
            });
        }
        plan
    }
}

/// A decode-ready context produced by a policy's `assemble` stage.
#[derive(Debug)]
pub struct ReadyContext {
    pub ctx: AssembledContext,
    pub buffer: Buffer,
    /// Table-1 sequence ratio of the assembled buffer.
    pub seq_ratio: f64,
    /// Table-1 recomputation ratio (set by recomputing policies).
    pub recompute_ratio: f64,
    /// KV bytes loaded for inference.
    pub kv_bytes: usize,
    /// Logits of the next token when the query was already fed during
    /// assemble (Recompute's joint prefill); `None` means the attend
    /// stage must run the incremental query prefill.
    pub logits: Option<Vec<f32>>,
    /// Next global decode position (joint layout).
    pub next_pos: i32,
}

impl ReadyContext {
    /// Wrap an assembled buffer with the standard ratio accounting and
    /// the joint-layout decode position.
    pub fn new(cfg: &ProfileConfig, ctx: AssembledContext, buffer: Buffer)
               -> ReadyContext {
        ReadyContext {
            seq_ratio: ctx.seq_ratio(cfg),
            kv_bytes: ctx.kv_bytes(cfg),
            recompute_ratio: 0.0,
            logits: None,
            next_pos: (cfg.ctx_len + cfg.query_len) as i32,
            ctx,
            buffer,
        }
    }
}

/// Where a session is in the stage lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Planned,
    DocsReady,
    Assembled,
    Attended,
    Done,
}

/// The forward pass one session needs from a fused decode round: the
/// just-emitted token, its global position, and the KV slot reserved
/// for it by [`ServeSession::decode_step_begin`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusedStep {
    pub token: i32,
    pub pos: i32,
    pub slot: usize,
}

/// State machine serving one request through the staged protocol.
/// Owns its [`Sample`] (so a persistent scheduler can keep sessions
/// alive across decode rounds after the originating request is gone);
/// generic over the policy reference so it works both with concrete
/// policies and `&dyn ContextPolicy` (the engine's case).
pub struct ServeSession<'a, P: ContextPolicy + ?Sized> {
    policy: &'a P,
    sample: Sample,
    cfg: ProfileConfig,
    plan: ServePlan,
    stage: Stage,
    docs: Vec<Arc<DocEntry>>,
    /// Holds the planned doc hashes pinned against tier eviction from
    /// `prefill_docs` until the session is dropped/finished.
    _pins: Option<PinGuard>,
    warm: bool,
    ready: Option<ReadyContext>,
    answer: Vec<i32>,
    plan_ms: f64,
    doc_prefill_ms: f64,
    queue_wait_ms: f64,
    ttft_ms: f64,
    decode_ms: f64,
}

impl<'a, P: ContextPolicy + ?Sized> ServeSession<'a, P> {
    /// Stage 1: run the policy's pure plan.
    pub fn new(policy: &'a P, cfg: &ProfileConfig, sample: Sample)
               -> ServeSession<'a, P> {
        let t = Instant::now();
        let plan = policy.plan(cfg, &sample);
        let plan_ms = t.elapsed().as_secs_f64() * 1e3;
        // a policy that never touches the doc cache is cold by definition
        let warm = plan.needs_doc_cache;
        ServeSession {
            policy,
            sample,
            cfg: cfg.clone(),
            plan,
            stage: Stage::Planned,
            docs: Vec::new(),
            _pins: None,
            warm,
            ready: None,
            answer: Vec::new(),
            plan_ms,
            doc_prefill_ms: 0.0,
            queue_wait_ms: 0.0,
            ttft_ms: 0.0,
            decode_ms: 0.0,
        }
    }

    pub fn plan(&self) -> &ServePlan {
        &self.plan
    }

    /// The request this session serves.
    pub fn sample(&self) -> &Sample {
        &self.sample
    }

    /// Record how long the request waited in the engine queue before
    /// planning started (reported in [`super::RunStats`]).
    pub fn set_queue_wait(&mut self, ms: f64) {
        self.queue_wait_ms = ms;
    }

    pub fn stage(&self) -> Stage {
        self.stage
    }

    pub fn is_done(&self) -> bool {
        self.stage == Stage::Done
    }

    /// Tokens generated so far.
    pub fn answer(&self) -> &[i32] {
        &self.answer
    }

    /// Credit document-prefill work performed outside this session
    /// (batch-level dedup): `ms` is this request's attributable share;
    /// `fresh` marks that a needed document was not cached before the
    /// batch, so the request's TTFT did not enjoy a fully warm cache.
    pub fn credit_shared_prefill(&mut self, ms: f64, fresh: bool) {
        self.doc_prefill_ms += ms;
        if fresh {
            self.warm = false;
        }
    }

    /// Stage 2: pin the planned doc hashes for the session's lifetime
    /// (a whole-document pin — [`crate::kvcache::PIN_ALL`] — covering
    /// every pool block, since assemble may select any span), then
    /// ensure every planned document KV exists in the tiered cache.
    /// Policies whose plans bound the spans they can touch may pin
    /// individual blocks instead via
    /// [`EngineDocCache::pin_planned_blocks`], letting the host tier
    /// evict a planned document's unpinned tail mid-session.
    pub fn prefill_docs(&mut self, model: &Model,
                        store: &mut EngineDocCache) -> Result<()> {
        if self.stage != Stage::Planned {
            bail!("prefill_docs called in stage {:?}", self.stage);
        }
        if self.plan.needs_doc_cache {
            let t = Instant::now();
            self._pins = Some(store.pin_planned(&self.plan.doc_hashes));
            for d in &self.sample.docs {
                let (e, hit) = store.get_or_prefill(model, d)?;
                self.warm &= hit.is_warm();
                self.docs.push(e);
            }
            self.doc_prefill_ms += t.elapsed().as_secs_f64() * 1e3;
        }
        self.stage = Stage::DocsReady;
        Ok(())
    }

    /// Stage 3: sparsify/recompute into a decode-ready context.
    pub fn assemble(&mut self, model: &Model) -> Result<()> {
        if self.stage != Stage::DocsReady {
            bail!("assemble called in stage {:?}", self.stage);
        }
        let t = Instant::now();
        let ready = self.policy.assemble(model, &self.docs, &self.sample)?;
        self.ttft_ms += t.elapsed().as_secs_f64() * 1e3;
        self.ready = Some(ready);
        self.stage = Stage::Assembled;
        Ok(())
    }

    /// Stage 4: incremental query prefill over the assembled cache
    /// (no-op when assemble already fed the query).
    pub fn attend(&mut self, model: &Model) -> Result<()> {
        if self.stage != Stage::Assembled {
            bail!("attend called in stage {:?}", self.stage);
        }
        let t = Instant::now();
        let ready = self.ready.as_mut().expect("assembled");
        if ready.logits.is_none() {
            let logits = common::prefill_query(model, &self.cfg,
                                               &mut ready.ctx, ready.buffer,
                                               &self.sample.query)?;
            ready.logits = Some(logits);
        }
        self.ttft_ms += t.elapsed().as_secs_f64() * 1e3;
        self.stage = Stage::Attended;
        Ok(())
    }

    /// Stage 5: emit at most one answer token. Returns the token, or
    /// `None` once the session is done (EOS or `answer_max` reached —
    /// the single bound check; no decode step runs whose logits would
    /// be discarded). Calling after completion keeps returning `None`.
    ///
    /// Implemented over the fused-round halves with a single-request
    /// dispatch, so this path and an engine's
    /// [`Model::decode_batch`]-driven rounds cannot diverge.
    pub fn decode_step(&mut self, model: &Model, sink: &mut dyn TokenSink)
                       -> Result<Option<i32>> {
        match self.stage {
            Stage::Assembled => self.attend(model)?,
            Stage::Attended => {}
            Stage::Done => return Ok(None),
            s => bail!("decode_step called in stage {s:?}"),
        }
        let (token, step) = self.decode_step_begin(sink)?;
        if let Some(step) = step {
            let t = Instant::now();
            let out = {
                let ready = self.ready.as_ref().expect("attended");
                model.decode(ready.buffer, step.token, step.pos,
                             step.slot as i32, &ready.ctx.kv,
                             &ready.ctx.valid)?
            };
            let ms = t.elapsed().as_secs_f64() * 1e3;
            self.decode_step_complete(step, out, ms)?;
        }
        Ok(token)
    }

    /// Attribute decode-loop host time: TTFT while the first token has
    /// not yet been emitted, decode time after (single place, so the
    /// EOS / bound / emit paths cannot drift apart).
    fn account_decode_time(&mut self, ms: f64, pre_first_token: bool) {
        if pre_first_token {
            self.ttft_ms += ms;
        } else {
            self.decode_ms += ms;
        }
    }

    /// Emit half of a fused decode round: consume the pending logits
    /// and emit at most one token (identical greedy/EOS/bound semantics
    /// to [`Self::decode_step`]). When the session wants another token,
    /// its KV slot is reserved here and the returned [`FusedStep`]
    /// describes the forward pass the caller must run — typically one
    /// [`Model::decode_batch`] dispatch covering every active session —
    /// before handing the output back via [`Self::decode_step_complete`].
    /// Returns `(emitted token, wanted forward pass)`; `(None, None)`
    /// means the session is done. Requires the session to be attended
    /// (the engine attends at admission).
    pub fn decode_step_begin(&mut self, sink: &mut dyn TokenSink)
                             -> Result<(Option<i32>, Option<FusedStep>)> {
        match self.stage {
            Stage::Attended => {}
            Stage::Done => return Ok((None, None)),
            s => bail!("decode_step_begin called in stage {s:?}"),
        }
        let t = Instant::now();
        let ready = self.ready.as_mut().expect("attended");
        let cur = Model::argmax(ready.logits.as_ref().expect("attended"));
        if cur == tok::EOS || self.answer.len() >= self.cfg.answer_max {
            self.stage = Stage::Done;
            let ms = t.elapsed().as_secs_f64() * 1e3;
            // never emitted: still pre-first-token
            let pre_first = self.answer.is_empty();
            self.account_decode_time(ms, pre_first);
            return Ok((None, None));
        }
        let first = self.answer.is_empty();
        self.answer.push(cur);
        sink.on_token(cur);
        if self.answer.len() >= self.cfg.answer_max {
            // bound reached: no further logits wanted
            self.stage = Stage::Done;
            let ms = t.elapsed().as_secs_f64() * 1e3;
            self.account_decode_time(ms, first);
            return Ok((Some(cur), None));
        }
        // reserve the token's KV slot now so the caller can batch the
        // forward pass across sessions
        let pos = ready.next_pos;
        let slot = ready.ctx.push_token(cur, pos)?;
        ready.next_pos += 1;
        // TTFT ends at the first emission; the forward pass computing
        // the NEXT token's logits is decode time
        let ms = t.elapsed().as_secs_f64() * 1e3;
        self.account_decode_time(ms, first);
        Ok((Some(cur), Some(FusedStep { token: cur, pos, slot })))
    }

    /// Completion half of a fused decode round: fold the externally
    /// computed forward pass for the [`FusedStep`] returned by
    /// [`Self::decode_step_begin`] back into the session — mirror the
    /// token's KV into the reserved slot, stage the logits for the next
    /// round, and account `dispatch_share_ms` (this session's share of
    /// the fused dispatch wall time) as decode time.
    pub fn decode_step_complete(&mut self, step: FusedStep, out: DecodeOut,
                                dispatch_share_ms: f64) -> Result<()> {
        if self.stage != Stage::Attended {
            bail!("decode_step_complete called in stage {:?}", self.stage);
        }
        let t = Instant::now();
        let ready = self.ready.as_mut().expect("attended");
        ready.ctx.write_token_kv(step.slot, &out.k_new, &out.v_new);
        ready.logits = Some(out.logits);
        self.decode_ms +=
            dispatch_share_ms + t.elapsed().as_secs_f64() * 1e3;
        Ok(())
    }

    /// The assembled buffer a fused dispatch reads for this session
    /// (valid from assemble onward).
    pub fn decode_inputs(&self) -> Result<(Buffer, &Tensor, &[f32])> {
        let ready = self
            .ready
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("decode_inputs before assemble"))?;
        Ok((ready.buffer, &ready.ctx.kv, &ready.ctx.valid))
    }

    /// Collapse the session into the legacy output shape. Valid at any
    /// stage (fields of unreached stages are zero).
    pub fn finish(self) -> PolicyOutput {
        let (seq_ratio, recompute_ratio, kv_bytes) = match &self.ready {
            Some(r) => (r.seq_ratio, r.recompute_ratio, r.kv_bytes),
            None => (0.0, 0.0, 0),
        };
        PolicyOutput {
            answer: self.answer,
            stats: RunStats {
                ttft_ms: self.ttft_ms,
                decode_ms: self.decode_ms,
                seq_ratio,
                recompute_ratio,
                kv_bytes,
                cache_warm: self.warm,
                plan_ms: self.plan_ms,
                queue_wait_ms: self.queue_wait_ms,
                doc_prefill_ms: self.doc_prefill_ms,
            },
        }
    }
}

/// The legacy blocking path: all stages in order, no streaming. This is
/// the default `ContextPolicy::run()` body.
pub fn serve_blocking<P: ContextPolicy + ?Sized>(
    policy: &P, model: &Model, store: &mut EngineDocCache,
    sample: &Sample) -> Result<PolicyOutput> {
    let mut session =
        ServeSession::new(policy, &model.cfg, sample.clone());
    session.prefill_docs(model, store)?;
    session.assemble(model)?;
    session.attend(model)?;
    let mut sink = NullSink;
    while session.decode_step(model, &mut sink)?.is_some() {}
    Ok(session.finish())
}

/// One unique document shared by a batch of planned requests. The
/// document's tokens are located through a *live* sharer's plan (its
/// `doc_hashes` mirror the sample's doc order) — never through a fixed
/// request index, which could go stale when that request is rejected
/// earlier in the wave.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SharedDoc {
    pub hash: u64,
    /// Every batch request sharing this document, in first-appearance
    /// order.
    pub sharers: Vec<usize>,
}

/// Group a batch's planned document prefills by content hash, in first
/// appearance order. The engine prefills each unique document once and
/// credits the cost evenly across its sharers — the multi-context RAG
/// hot path where the same retrieved document appears in many
/// concurrent requests.
pub fn dedup_doc_plans(plans: &[Option<&ServePlan>]) -> Vec<SharedDoc> {
    let mut order: Vec<SharedDoc> = Vec::new();
    let mut seen: std::collections::HashMap<u64, usize> =
        std::collections::HashMap::new();
    for (i, plan) in plans.iter().enumerate() {
        let Some(plan) = plan else { continue };
        if !plan.needs_doc_cache {
            continue;
        }
        for &h in &plan.doc_hashes {
            match seen.get(&h) {
                Some(&k) => {
                    if !order[k].sharers.contains(&i) {
                        order[k].sharers.push(i);
                    }
                }
                None => {
                    seen.insert(h, order.len());
                    order.push(SharedDoc { hash: h, sharers: vec![i] });
                }
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_with(hashes: Vec<u64>) -> ServePlan {
        ServePlan {
            policy: "t".to_string(),
            needs_doc_cache: true,
            doc_hashes: hashes,
            buffer: Buffer::Full,
            fixed_spans: Vec::new(),
            dynamic_blocks: 0,
            planned_recompute_tokens: 0,
        }
    }

    #[test]
    fn docs_only_plan_hashes_content() {
        let s = Sample {
            docs: vec![vec![1, 2], vec![3, 4]],
            query: vec![2, 5, 16, 0, 3],
            answer: vec![],
            qtype: "t".into(),
        };
        let p = ServePlan::docs_only("Reuse", true, &s);
        assert_eq!(p.doc_hashes.len(), 2);
        assert_eq!(p.doc_hashes[0], doc_hash(&[1, 2]));
        assert_ne!(p.doc_hashes[0], p.doc_hashes[1]);
        let q = ServePlan::docs_only("Recompute", false, &s);
        assert!(q.doc_hashes.is_empty());
        assert!(!q.needs_doc_cache);
    }

    #[test]
    fn dedup_groups_shared_docs_across_requests() {
        // req 0: docs A, B; req 1: docs B, C; req 2 (None) skipped;
        // req 3: doc A again
        let p0 = plan_with(vec![10, 20]);
        let p1 = plan_with(vec![20, 30]);
        let p3 = plan_with(vec![10]);
        let plans = vec![Some(&p0), Some(&p1), None, Some(&p3)];
        let shared = dedup_doc_plans(&plans);
        assert_eq!(shared.len(), 3); // A, B, C unique
        let a = &shared[0];
        assert_eq!(a.hash, 10);
        assert_eq!(a.sharers, vec![0, 3]);
        let b = &shared[1];
        assert_eq!(b.hash, 20);
        assert_eq!(b.sharers, vec![0, 1]);
        let c = &shared[2];
        assert_eq!(c.hash, 30);
        assert_eq!(c.sharers, vec![1]);
    }

    #[test]
    fn dedup_ignores_cacheless_plans() {
        let mut p = plan_with(vec![10]);
        p.needs_doc_cache = false;
        let plans = vec![Some(&p)];
        assert!(dedup_doc_plans(&plans).is_empty());
    }

    #[test]
    fn dedup_same_doc_twice_in_one_request() {
        let p = plan_with(vec![10, 10]);
        let plans = vec![Some(&p)];
        let shared = dedup_doc_plans(&plans);
        assert_eq!(shared.len(), 1);
        assert_eq!(shared[0].sharers, vec![0]);
    }

    #[test]
    fn sinks_deliver_in_order() {
        let mut c = CollectSink::default();
        c.on_token(5);
        c.on_token(7);
        assert_eq!(c.0, vec![5, 7]);
        let mut seen = Vec::new();
        {
            let mut f = FnSink(|t| seen.push(t));
            f.on_token(9);
        }
        assert_eq!(seen, vec![9]);
        NullSink.on_token(1); // no-op
    }
}
