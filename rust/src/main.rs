//! `samkv` — leader binary: serving, evaluation, and every paper
//! experiment behind subcommands.
//!
//! ```text
//! samkv info                               # manifest / profile summary
//! samkv eval    --profile s4 --dataset hotpot-sim --policy all --samples 50
//! samkv serve   --profile s4 --port 7070 --engines 1 --policy SamKV-fusion
//! samkv table1  --profile s4 --samples 30       (also: fig1, table3,
//!               table4, fig7, fig8, throughput, chaos)
//! samkv analyze --profile s4                    # Fig.7 + Fig.8 dump
//! ```

use std::sync::Arc;
use std::time::Duration;

use samkv::bench::experiments as exp;
use samkv::cli::Args;
use samkv::config::{DiskWriteback, KvCodecKind, ServingConfig};
use samkv::coordinator::{Engine, Router};
use samkv::eval::evaluate;
use samkv::faultinject::FaultPlan;
use samkv::kvcache::{
    codec_for, eviction_policy_by_name, DiskDocCache, HostDocCache,
};
use samkv::metrics::Metrics;
use samkv::policies::{all_policies, policy_by_name};
use samkv::runtime::artifacts_dir;
use samkv::server::Server;
use samkv::{info, logging};

fn main() {
    let args = Args::parse_env();
    logging::set_level(logging::level_from_str(
        &args.get_str("log", "info")));
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> samkv::Result<()> {
    let profile = args.get_str("profile", "s4");
    let samples = args.get::<usize>("samples", 50);
    match cmd {
        "info" => info_cmd(),
        "eval" => eval_cmd(args, &profile, samples),
        "serve" => serve_cmd(args, &profile),
        "table1" => {
            let m = exp::load_model(&profile)?;
            let ds = exp::load_dataset(
                &m, &args.get_str("dataset", "hotpot-sim"))?;
            exp::table1(&m, &ds, samples)?;
            Ok(())
        }
        "fig1" => {
            let m = exp::load_model(&profile)?;
            let ds = exp::load_dataset(
                &m, &args.get_str("dataset", "hotpot-sim"))?;
            exp::fig1(&m, &ds, samples)?;
            Ok(())
        }
        "table3" => {
            let m = exp::load_model(&profile)?;
            exp::table3(&m, samples)?;
            Ok(())
        }
        "table4" => {
            let m = exp::load_model(&profile)?;
            exp::table4(&m, samples)?;
            Ok(())
        }
        "fig7" => {
            let m = exp::load_model(&profile)?;
            let ds = exp::load_dataset(
                &m, &args.get_str("dataset", "hotpot-sim"))?;
            exp::fig7(&m, &ds, args.get::<usize>("docs", 16))?;
            Ok(())
        }
        "fig8" => {
            let m = exp::load_model(&profile)?;
            exp::fig8(&m, args.get::<usize>("docs", 16))?;
            Ok(())
        }
        "analyze" => {
            let m = exp::load_model(&profile)?;
            let ds = exp::load_dataset(
                &m, &args.get_str("dataset", "hotpot-sim"))?;
            exp::fig7(&m, &ds, args.get::<usize>("docs", 16))?;
            exp::fig8(&m, args.get::<usize>("docs", 16))?;
            Ok(())
        }
        "throughput" => {
            let defaults = ServingConfig::default();
            exp::throughput(
                &profile,
                &args.get_str("policy", "SamKV-fusion"),
                args.get::<usize>("requests", 64),
                args.get::<usize>("unique", 8),
                args.get::<usize>("engines", 2),
                &exp::parse_list::<usize>(
                    &args.get_str("batch-sizes", "1,4"))?,
                &exp::parse_list::<f64>(&args.get_str("rates", "0,32"))?,
                args.get_str("kv-codec", defaults.kv_codec.name())
                    .parse::<KvCodecKind>()?,
                args.get::<usize>("kv-hot-blocks", defaults.kv_hot_blocks),
            )?;
            Ok(())
        }
        "front" => {
            let nodes: Vec<String> = args
                .get_str("nodes", "")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if nodes.is_empty() {
                anyhow::bail!("front needs --nodes host:port,host:port,…");
            }
            let port = args.get::<u16>("port", 7170);
            let fe = samkv::server::front::FrontEnd::new(nodes);
            fe.run(&format!("127.0.0.1:{port}"), |p| {
                info!("front end listening on 127.0.0.1:{p}");
                println!("READY {p}");
            })?;
            Ok(())
        }
        "peers" => {
            exp::peers_run(
                &profile,
                &args.get_str("policy", "SamKV-fusion"),
                args.get::<usize>("requests", 16),
                args.get::<usize>("unique", 4),
                args.opt("fault-plan"),
            )?;
            Ok(())
        }
        "chaos" => {
            exp::chaos_run(
                &profile,
                &args.get_str("policy", "SamKV-fusion"),
                args.get::<usize>("requests", 24),
                args.get::<usize>("unique", 4),
                args.get::<usize>("engines", 2),
                &args.get_str(
                    "fault-plan",
                    "seed=7;engine_kill:engine=0:after=3;\
                     disk_read:after=1:every=2;disk_latency:ms=2:every=3",
                ),
                args.get::<u64>("request-timeout-ms", 10_000),
            )?;
            Ok(())
        }
        "help" | _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "samkv — sparse attention across multiple-context KV cache\n\n\
         subcommands:\n  \
         info                          manifest summary\n  \
         eval --profile P --dataset D --policy NAME|all --samples N\n  \
         serve --profile P --port N --engines N --policy NAME\n  \
               --host-cache-mb N (0 = auto-size) --eviction lru|cost-aware\n  \
               --kv-block-tokens N (pool block span; eviction/spill/\n  \
                sharing granularity, default 64)\n  \
               --kv-codec f32|f16|int8 (encoding for cold host blocks\n  \
                and disk records; f32 = lossless, f16 ~2x smaller,\n  \
                int8 ~4x smaller per-block absmax; default f32)\n  \
               --kv-hot-blocks N (per-document head blocks kept as raw\n  \
                pooled f32 under a lossy codec, default 4)\n  \
               --max-batch N --batch-window-ms N --max-active N\n  \
               (continuous batching: admission wave size, gather window,\n  \
                in-flight session cap)\n  \
               --disk-cache-dir PATH (persistent doc-KV tier; restarts\n  \
                serve seen docs with zero prefills)\n  \
               --disk-cache-mb N (0 = unbounded)\n  \
               --disk-writeback evict|through|off\n  \
               --request-timeout-ms N (per-request deadline across\n  \
                queue, prefill, and decode; 0 = off)\n  \
               --request-retries N --retry-backoff-ms N (re-dispatch\n  \
                failed requests to surviving engines with jittered\n  \
                exponential backoff)\n  \
               --disk-breaker-threshold N (consecutive disk I/O errors\n  \
                before the tier opens its circuit breaker; 0 = off)\n  \
               --disk-breaker-probe-ms N (half-open probe interval)\n  \
               --fault-plan SPEC (deterministic fault injection, e.g.\n  \
                \"seed=7;disk_read:after=1:every=2;\\\n  \
                 engine_kill:engine=0:after=3\")\n  \
               --peers host:port,… --node-id I (multi-node host-tier\n  \
                sharding: rendezvous owners serve peer_get fetches so\n  \
                each unique doc prefills once cluster-wide; the list\n  \
                must be identical on every node and include this one)\n  \
               --peer-timeout-ms N (peer fetch deadline, default 250;\n  \
                any peer error degrades to a local prefill)\n  \
         front --nodes host:port,host:port,… --port N\n  \
               (thin cluster front end: owner-aware placement via the\n  \
                engine router, node retry/mark-down, fan-out metrics)\n  \
         peers --policy NAME --requests N --unique N [--fault-plan SPEC]\n  \
               (two-node smoke: proves cluster-wide exactly-once\n  \
                prefill and prints one JSON row)\n  \
         table1|fig1|table3|table4|fig7|fig8  (paper experiments)\n  \
         throughput --policy NAME --requests N --unique N --engines N\n  \
                    --batch-sizes 1,4 --rates 0,32\n  \
                    --kv-codec f32|f16|int8 --kv-hot-blocks N  (sweep)\n  \
         chaos --policy NAME --requests N --unique N --engines N\n  \
               --fault-plan SPEC --request-timeout-ms N\n  \
               (baseline + faulted pass; asserts 100% completion)\n  \
         analyze --profile P           Fig.7 + Fig.8 analytics"
    );
}

fn info_cmd() -> samkv::Result<()> {
    let dir = artifacts_dir();
    let manifest = samkv::runtime::Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    for (name, p) in &manifest.profiles {
        println!(
            "profile {name}: {} layers, d={}, {} heads x {}, docs {}x{}, \
             block {}, sparse buffer {}, entrypoints: {}",
            p.config.n_layers, p.config.d_model, p.config.n_heads,
            p.config.head_dim, p.config.n_docs, p.config.doc_len,
            p.config.block_size, p.config.sparse_len,
            p.entrypoints.keys().cloned().collect::<Vec<_>>().join(", ")
        );
        for (ds, path) in &p.datasets {
            println!("  dataset {ds}: {path}");
        }
    }
    Ok(())
}

fn eval_cmd(args: &Args, profile: &str, samples: usize)
            -> samkv::Result<()> {
    let model = exp::load_model(profile)?;
    let ds = exp::load_dataset(&model,
                               &args.get_str("dataset", "hotpot-sim"))?;
    let which = args.get_str("policy", "all");
    let policies = if which == "all" {
        all_policies()
    } else {
        vec![policy_by_name(&which)
            .ok_or_else(|| anyhow::anyhow!("unknown policy `{which}`"))?]
    };
    let mut tbl = samkv::bench::Table::new(&[
        "policy", "F1", "EM", "TTFT", "seq%", "rec%", "KV KiB",
    ]);
    for p in policies {
        let r = evaluate(&model, p.as_ref(), &ds, samples)?;
        tbl.row(vec![
            r.policy.clone(),
            format!("{:.2}", r.f1),
            format!("{:.2}", r.em),
            samkv::bench::ms(r.mean_ttft_ms),
            format!("{:.1}", 100.0 * r.mean_seq_ratio),
            format!("{:.1}", 100.0 * r.mean_recompute_ratio),
            format!("{:.0}", r.mean_kv_bytes / 1024.0),
        ]);
    }
    tbl.print();
    Ok(())
}

fn serve_cmd(args: &Args, profile: &str) -> samkv::Result<()> {
    let port = args.get::<u16>("port", 7070);
    let n_engines = args.get::<usize>("engines", 1);
    let policy = args.get_str("policy", "SamKV-fusion");
    let metrics = Arc::new(Metrics::new());
    let defaults = ServingConfig::default();
    let max_batch = args.get::<usize>("max-batch", defaults.max_batch);
    let cfg = ServingConfig {
        profile: profile.to_string(),
        port,
        max_batch,
        batch_window_ms: args
            .get::<u64>("batch-window-ms", defaults.batch_window_ms),
        // unless pinned explicitly, grow the pool to fit a full
        // admission wave so `--max-batch 16` is not silently clamped
        max_active: args.get::<usize>("max-active",
                                      defaults.max_active.max(max_batch)),
        disk_cache_dir: args.get_str("disk-cache-dir", ""),
        disk_cache_mb: args.get::<usize>("disk-cache-mb",
                                         defaults.disk_cache_mb),
        disk_writeback: args
            .get_str("disk-writeback", defaults.disk_writeback.name())
            .parse::<DiskWriteback>()?,
        kv_block_tokens: args.get::<usize>("kv-block-tokens",
                                           defaults.kv_block_tokens),
        kv_codec: args
            .get_str("kv-codec", defaults.kv_codec.name())
            .parse::<KvCodecKind>()?,
        kv_hot_blocks: args.get::<usize>("kv-hot-blocks",
                                         defaults.kv_hot_blocks),
        fault_plan: match args.opt("fault-plan") {
            Some(spec) => Some(Arc::new(FaultPlan::parse(spec)?)),
            None => None,
        },
        request_timeout_ms: args.get::<u64>("request-timeout-ms",
                                            defaults.request_timeout_ms),
        request_retries: args.get::<usize>("request-retries",
                                           defaults.request_retries),
        retry_backoff_ms: args.get::<u64>("retry-backoff-ms",
                                          defaults.retry_backoff_ms),
        disk_breaker_threshold: args.get::<usize>(
            "disk-breaker-threshold", defaults.disk_breaker_threshold),
        disk_breaker_probe_ms: args.get::<u64>(
            "disk-breaker-probe-ms", defaults.disk_breaker_probe_ms),
        peers: {
            let list = args.get_str("peers", "");
            if list.is_empty() {
                Vec::new()
            } else {
                list.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            }
        },
        node_id: args.get::<usize>("node-id", defaults.node_id),
        peer_timeout_ms: args.get::<u64>("peer-timeout-ms",
                                         defaults.peer_timeout_ms),
        ..defaults
    };
    if !cfg.peers.is_empty() && cfg.node_id >= cfg.peers.len() {
        anyhow::bail!("--node-id {} out of range for {} peers",
                      cfg.node_id, cfg.peers.len());
    }
    if let Some(plan) = cfg.fault_plan.as_deref() {
        info!("fault injection armed: {} (seed {})",
              plan.spec(), plan.seed());
    }
    // the shared host doc-cache tier beneath every engine's residency
    // tier: one prefill per unique document process-wide. Default is
    // auto-sized (engines raise the budget from model geometry), so
    // the host tier is bounded without operator tuning.
    let host_mb = args.get::<usize>("host-cache-mb", 0);
    let eviction = args.get_str("eviction", "lru");
    let evict_policy = eviction_policy_by_name(&eviction)
        .ok_or_else(|| anyhow::anyhow!("unknown eviction `{eviction}`"))?;
    // one codec instance per serving stack, shared by the host pool
    // and the disk tier so compression stats aggregate in one place
    let codec = codec_for(cfg.kv_codec);
    let mut host = if host_mb == 0 {
        HostDocCache::auto_sized(evict_policy)
    } else {
        HostDocCache::with_policy(host_mb * 1024 * 1024, evict_policy)
    }
    .with_block_tokens(cfg.kv_block_tokens)
    .with_codec(Arc::clone(&codec), cfg.kv_hot_blocks);
    // the persistent disk tier beneath the host tier: host evictions
    // spill instead of dropping, and a restarted server re-serves
    // previously-seen documents with zero model prefills
    if !cfg.disk_cache_dir.is_empty() {
        let budget = if cfg.disk_cache_mb == 0 {
            usize::MAX
        } else {
            cfg.disk_cache_mb * 1024 * 1024
        };
        let mut disk = DiskDocCache::open(&cfg.disk_cache_dir, budget)?
            .with_codec(Arc::clone(&codec))
            .with_breaker(cfg.disk_breaker_threshold,
                          Duration::from_millis(cfg.disk_breaker_probe_ms));
        if let Some(plan) = &cfg.fault_plan {
            disk = disk.with_faults(Arc::clone(plan));
        }
        let disk = Arc::new(disk);
        info!("disk cache tier at {} ({} entries, {}, writeback {})",
              cfg.disk_cache_dir,
              disk.len(),
              if cfg.disk_cache_mb == 0 { "unbounded".to_string() }
              else { format!("{}MiB", cfg.disk_cache_mb) },
              cfg.disk_writeback.name());
        host = host.with_disk(disk, cfg.disk_writeback);
    }
    // the cluster peer tier: on a local miss of a remotely-owned
    // document, ask the rendezvous owner for the serialized entry
    // before paying a model prefill — the exactly-once guarantee goes
    // cluster-wide. Errors and timeouts degrade to local prefills.
    if !cfg.peers.is_empty() {
        let mut cluster = samkv::server::peers::ClusterPeers::new(
            cfg.node_id, cfg.peers.clone(), cfg.peer_timeout_ms,
            Arc::clone(&metrics))
            .with_faults(cfg.fault_plan.clone());
        if let Some(ms) = args.opt("peer-down-cooldown-ms") {
            cluster = cluster.with_cooldown_ms(ms.parse()?);
        }
        info!("peer tier armed: node {} of {} ({}ms timeout)",
              cfg.node_id, cfg.peers.len(), cfg.peer_timeout_ms);
        host = host.with_peers(Arc::new(cluster));
    }
    let host = Arc::new(host);
    let router = Arc::new(Router::new(n_engines));
    info!("spawning {n_engines} engine(s), profile {profile}, default \
           policy {policy}, host cache {} ({eviction}, {}-token KV \
           blocks, codec {} past {} hot blocks), continuous batching \
           (wave {}, window {}ms, max active {})",
          if host_mb == 0 { "auto-sized".to_string() }
          else { format!("{host_mb}MiB") },
          cfg.kv_block_tokens, cfg.kv_codec.name(), cfg.kv_hot_blocks,
          cfg.max_batch, cfg.batch_window_ms, cfg.max_active);
    let engines: Vec<Engine> = (0..n_engines)
        .map(|i| {
            Engine::spawn(i, artifacts_dir(), cfg.clone(), policy.clone(),
                          Arc::clone(&metrics), Arc::clone(&host),
                          Some(router.residency_handle(i)))
        })
        .collect::<samkv::Result<_>>()?;
    let handles = engines.iter().map(|e| e.handle()).collect();
    let server = Server::with_router(handles, metrics, router)
        .with_resilience(cfg.request_retries, cfg.retry_backoff_ms,
                         cfg.request_timeout_ms)
        .with_faults(cfg.fault_plan.clone())
        // always attach the host tier so this node can answer
        // `peer_get` (a single-node server is a valid one-node cluster
        // — and a warm-start donor for `--disk-writeback off` replicas)
        .with_host(Arc::clone(&host));
    server.run(&format!("127.0.0.1:{port}"), |p| {
        info!("listening on 127.0.0.1:{p}");
        println!("READY {p}");
    })?;
    Ok(())
}
