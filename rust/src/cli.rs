//! CLI substrate (no clap offline): subcommand + `--key value` /
//! `--key=value` / boolean `--flag` parsing with typed getters.

use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining non-flag tokens.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let tokens: Vec<String> = items.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(rest) = t.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    args.flags
                        .insert(rest.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.switches.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Typed flag with default.
    pub fn get<T: FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Boolean switch (`--verbose`) or explicit `--verbose true/false`.
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
            || self
                .flags
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        // NB `--switch` followed by a non-flag token binds the token as a
        // value; bare switches go last or use `--switch=true`.
        let a = parse("eval x y --profile s4 --samples=50 --verbose");
        assert_eq!(a.command.as_deref(), Some("eval"));
        assert_eq!(a.get_str("profile", "tiny"), "s4");
        assert_eq!(a.get::<usize>("samples", 0), 50);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["x", "y"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("serve");
        assert_eq!(a.get::<u16>("port", 7070), 7070);
        assert_eq!(a.get_str("profile", "tiny"), "tiny");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn bool_flag_styles() {
        assert!(parse("x --flag").has("flag"));
        assert!(parse("x --flag=true").has("flag"));
        assert!(parse("x --flag 1").has("flag"));
        assert!(!parse("x --flag false").has("flag"));
        // trailing switch before another switch
        let a = parse("x --a --b");
        assert!(a.has("a") && a.has("b"));
    }

    #[test]
    fn bad_parse_falls_back() {
        let a = parse("x --n notanumber");
        assert_eq!(a.get::<usize>("n", 3), 3);
    }
}
