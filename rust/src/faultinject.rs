//! Seeded, deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] is parsed from a compact spec string (`--fault-plan`)
//! and threaded through [`crate::config::ServingConfig`] into every
//! layer that can fail in production: the disk tier (I/O errors, added
//! latency, payload corruption, codec decode failure), the admission
//! pipeline (doc-prefill failure), and the decode loop (engine
//! thread death mid-round). Each injection point calls
//! [`FaultPlan::should`] / [`FaultPlan::should_for`] with its
//! [`FaultSite`]; the plan decides deterministically — same spec, same
//! seed, same call sequence ⇒ same faults — so chaos runs are
//! reproducible and CI can assert exact self-healing behavior.
//!
//! Spec grammar (semicolon-separated clauses):
//!
//! ```text
//! seed=7;engine_kill:engine=0:after=3;disk_read:after=1:every=2;
//! disk_latency:ms=5:every=3;corrupt_block:count=2
//! ```
//!
//! Each non-`seed` clause names a site followed by `key=value` options:
//!
//! | key      | meaning                                               |
//! |----------|-------------------------------------------------------|
//! | `after`  | skip the first N trials at this site (default 0)      |
//! | `every`  | then inject on every Nth eligible trial (default: all)|
//! | `prob`   | instead of `every`: inject with probability p (seeded)|
//! | `count`  | stop after N injections (default 0 = unlimited)       |
//! | `ms`     | latency to add, for `disk_latency` (default 1)        |
//! | `engine` | only fire for this engine index (`engine_kill`)       |
//!
//! With neither `every` nor `prob`, every trial past `after` injects
//! (up to `count`) — the fully deterministic default.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::rng::Rng;
use crate::sync::Mutex;

/// Named injection points. Every fault the plan can produce is pulled
/// at one of these sites by the owning subsystem.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Disk-tier read: `fs::read` returns an injected I/O error
    /// (counts toward the circuit breaker like a real error).
    DiskRead,
    /// Disk-tier write: spill/writeback fails with an injected I/O
    /// error (also breaker-visible).
    DiskWrite,
    /// Disk-tier latency: sleep `ms` before the read proceeds.
    DiskLatency,
    /// Flip a byte inside an encoded block payload before it is
    /// written, so the per-block checksum catches it on read-back.
    CorruptBlock,
    /// Codec decode failure on disk read-back: the record's blocks
    /// decode as corrupt (dropped alone, entry kept incomplete).
    CodecDecode,
    /// Shared doc prefill fails for one admission wave.
    DocPrefill,
    /// The engine's decode thread dies mid-round (exits its loop,
    /// dropping every in-flight session).
    EngineKill,
    /// Peer host-tier fetch fails as an injected miss (falling back
    /// to disk/prefill like a real peer error), after sleeping the
    /// rule's `ms` first — so one site carries both the error arm and
    /// the latency arm (`ms=0` for a pure fast failure).
    PeerFetch,
}

/// Number of distinct [`FaultSite`]s (array-table size).
pub const N_SITES: usize = 8;

impl FaultSite {
    /// All sites, in stable counter order.
    pub const ALL: [FaultSite; N_SITES] = [
        FaultSite::DiskRead,
        FaultSite::DiskWrite,
        FaultSite::DiskLatency,
        FaultSite::CorruptBlock,
        FaultSite::CodecDecode,
        FaultSite::DocPrefill,
        FaultSite::EngineKill,
        FaultSite::PeerFetch,
    ];

    /// Stable spec/metrics name of this site.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::DiskRead => "disk_read",
            FaultSite::DiskWrite => "disk_write",
            FaultSite::DiskLatency => "disk_latency",
            FaultSite::CorruptBlock => "corrupt_block",
            FaultSite::CodecDecode => "codec_decode",
            FaultSite::DocPrefill => "doc_prefill",
            FaultSite::EngineKill => "engine_kill",
            FaultSite::PeerFetch => "peer_fetch",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&s| s == self).unwrap()
    }

    fn parse(s: &str) -> Result<FaultSite> {
        Self::ALL
            .iter()
            .copied()
            .find(|site| site.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> =
                    Self::ALL.iter().map(|s| s.name()).collect();
                anyhow::anyhow!("unknown fault site `{s}` (expected one \
                                 of {})", names.join("|"))
            })
    }
}

/// One site's injection rule (see the module-level spec grammar).
#[derive(Debug, Clone, PartialEq)]
struct Rule {
    /// Skip the first `after` trials.
    after: u64,
    /// Inject on every Nth eligible trial; 0 = use `prob` instead.
    every: u64,
    /// Injection probability when `every` is 0 (default 1.0).
    prob: f32,
    /// Stop after this many injections; 0 = unlimited.
    count: u64,
    /// Added latency in ms (only meaningful for `DiskLatency`).
    ms: u64,
    /// Only fire when the caller passes this engine index.
    engine: Option<usize>,
}

impl Default for Rule {
    fn default() -> Self {
        Rule { after: 0, every: 0, prob: 1.0, count: 0, ms: 1, engine: None }
    }
}

/// Mutable per-site trial state, behind one mutex per site.
struct SiteState {
    trials: u64,
    injected: u64,
    rng: Rng,
}

/// A parsed, seeded fault schedule. Shared (`Arc`) between the server,
/// every engine, and the disk tier; all counters are process-wide.
pub struct FaultPlan {
    spec: String,
    seed: u64,
    rules: [Option<Rule>; N_SITES],
    state: [Mutex<SiteState>; N_SITES],
    /// Lock-free injection counters mirroring `state[i].injected`,
    /// readable without contending the trial path.
    injected: [AtomicU64; N_SITES],
}

impl FaultPlan {
    /// Parse a spec string (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 0u64;
        let mut rules: [Option<Rule>; N_SITES] = Default::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(v) = clause.strip_prefix("seed=") {
                seed = v
                    .parse()
                    .with_context(|| format!("bad seed `{v}`"))?;
                continue;
            }
            let mut parts = clause.split(':');
            let site = FaultSite::parse(parts.next().unwrap_or(""))?;
            let mut rule = Rule::default();
            for kv in parts {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!("bad fault option `{kv}` in \
                                     `{clause}` (expected key=value)")
                })?;
                let bad =
                    || format!("bad value `{v}` for `{k}` in `{clause}`");
                match k {
                    "after" => rule.after = v.parse().with_context(bad)?,
                    "every" => rule.every = v.parse().with_context(bad)?,
                    "prob" => rule.prob = v.parse().with_context(bad)?,
                    "count" => rule.count = v.parse().with_context(bad)?,
                    "ms" => rule.ms = v.parse().with_context(bad)?,
                    "engine" => {
                        rule.engine = Some(v.parse().with_context(bad)?)
                    }
                    other => bail!("unknown fault option `{other}` in \
                                    `{clause}`"),
                }
            }
            if rules[site.index()].is_some() {
                bail!("duplicate clause for fault site `{}`", site.name());
            }
            rules[site.index()] = Some(rule);
        }
        let state = std::array::from_fn(|i| {
            Mutex::named("fault-plan", SiteState {
                trials: 0,
                injected: 0,
                rng: Rng::new(seed ^ (0x5117_u64 << 16) ^ i as u64),
            })
        });
        Ok(FaultPlan {
            spec: spec.to_string(),
            seed,
            rules,
            state,
            injected: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    /// The spec string this plan was parsed from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// The plan's RNG seed (`seed=` clause; 0 if absent).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// True if the plan has a rule for `site` at all (cheap pre-check
    /// for callers that would otherwise prepare injection inputs).
    pub fn arms(&self, site: FaultSite) -> bool {
        self.rules[site.index()].is_some()
    }

    /// Record one trial at `site` and decide whether to inject.
    pub fn should(&self, site: FaultSite) -> bool {
        self.decide(site, None)
    }

    /// Like [`FaultPlan::should`], for sites scoped to one engine: a
    /// rule carrying `engine=N` only fires when `engine == N`.
    pub fn should_for(&self, site: FaultSite, engine: usize) -> bool {
        self.decide(site, Some(engine))
    }

    /// Latency-site trial: `Some(ms)` when a sleep should be injected.
    pub fn latency_ms(&self, site: FaultSite) -> Option<u64> {
        if self.should(site) {
            self.rules[site.index()].as_ref().map(|r| r.ms)
        } else {
            None
        }
    }

    fn decide(&self, site: FaultSite, engine: Option<usize>) -> bool {
        let i = site.index();
        let Some(rule) = &self.rules[i] else {
            return false;
        };
        if let Some(want) = rule.engine {
            if engine != Some(want) {
                return false;
            }
        }
        let mut st = self.state[i].lock();
        if rule.count > 0 && st.injected >= rule.count {
            return false;
        }
        st.trials += 1;
        if st.trials <= rule.after {
            return false;
        }
        let eligible = st.trials - rule.after;
        let fire = if rule.every > 0 {
            eligible % rule.every == 0
        } else if rule.prob < 1.0 {
            st.rng.next_f32() < rule.prob
        } else {
            true
        };
        if fire {
            st.injected += 1;
            self.injected[i].fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Injections fired so far at one site.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::Relaxed)
    }

    /// Total injections fired across all sites.
    pub fn total_injected(&self) -> u64 {
        self.injected
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    /// `(site name, injections)` for every site, in stable order —
    /// the metrics/bench folding source.
    pub fn counts(&self) -> Vec<(&'static str, u64)> {
        FaultSite::ALL
            .iter()
            .map(|&s| (s.name(), self.injected(s)))
            .collect()
    }
}

// Manual impl: `ServingConfig` (which holds `Option<Arc<FaultPlan>>`)
// derives Debug, and the mutex/atomic state tables have no useful
// debug form — the spec string is the whole identity.
impl fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("FaultPlan")
            .field("spec", &self.spec)
            .field("seed", &self.seed)
            .field("total_injected", &self.total_injected())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_fire_after_every_count() {
        let p =
            FaultPlan::parse("seed=7;disk_read:after=2:every=2:count=2")
                .unwrap();
        assert_eq!(p.seed(), 7);
        assert!(p.arms(FaultSite::DiskRead));
        assert!(!p.arms(FaultSite::DiskWrite));
        // trials 1,2 skipped (after=2); then every 2nd eligible trial
        // fires: trial 4 (eligible 2), trial 6 (eligible 4); count=2
        // stops it there.
        let fired: Vec<bool> =
            (0..8).map(|_| p.should(FaultSite::DiskRead)).collect();
        assert_eq!(
            fired,
            vec![false, false, false, true, false, true, false, false]
        );
        assert_eq!(p.injected(FaultSite::DiskRead), 2);
        assert_eq!(p.total_injected(), 2);
    }

    #[test]
    fn deterministic_default_fires_every_trial_past_after() {
        let p = FaultPlan::parse("engine_kill:after=1").unwrap();
        assert!(!p.should(FaultSite::EngineKill));
        assert!(p.should(FaultSite::EngineKill));
        assert!(p.should(FaultSite::EngineKill));
    }

    #[test]
    fn engine_scoping() {
        let p = FaultPlan::parse("engine_kill:engine=1").unwrap();
        // wrong engine (and the engine-less form) never fire, and do
        // not consume trials
        assert!(!p.should_for(FaultSite::EngineKill, 0));
        assert!(!p.should(FaultSite::EngineKill));
        assert!(p.should_for(FaultSite::EngineKill, 1));
        assert_eq!(p.injected(FaultSite::EngineKill), 1);
    }

    #[test]
    fn prob_is_seeded_and_reproducible() {
        let fire = |seed: u64| -> Vec<bool> {
            let p = FaultPlan::parse(&format!(
                "seed={seed};disk_write:prob=0.5"
            ))
            .unwrap();
            (0..64).map(|_| p.should(FaultSite::DiskWrite)).collect()
        };
        assert_eq!(fire(3), fire(3), "same seed must reproduce");
        assert_ne!(fire(3), fire(4), "different seeds must differ");
        let n = fire(3).iter().filter(|&&b| b).count();
        assert!(n > 8 && n < 56, "prob=0.5 should fire ~half: {n}");
    }

    #[test]
    fn latency_site_returns_ms() {
        let p =
            FaultPlan::parse("disk_latency:ms=5:every=2").unwrap();
        assert_eq!(p.latency_ms(FaultSite::DiskLatency), None);
        assert_eq!(p.latency_ms(FaultSite::DiskLatency), Some(5));
        assert_eq!(p.latency_ms(FaultSite::DiskLatency), None);
    }

    #[test]
    fn counts_cover_all_sites_in_stable_order() {
        let p = FaultPlan::parse("corrupt_block").unwrap();
        assert!(p.should(FaultSite::CorruptBlock));
        let names: Vec<&str> =
            p.counts().iter().map(|&(n, _)| n).collect();
        assert_eq!(
            names,
            vec!["disk_read", "disk_write", "disk_latency",
                 "corrupt_block", "codec_decode", "doc_prefill",
                 "engine_kill", "peer_fetch"]
        );
        assert_eq!(p.counts()[3], ("corrupt_block", 1));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("bogus_site").is_err());
        assert!(FaultPlan::parse("disk_read:after").is_err());
        assert!(FaultPlan::parse("disk_read:volume=11").is_err());
        assert!(FaultPlan::parse("seed=x").is_err());
        assert!(FaultPlan::parse("disk_read;disk_read:after=1").is_err(),
                "duplicate site clauses must be rejected");
        // empty clauses (trailing semicolons) are fine
        assert!(FaultPlan::parse("").unwrap().counts().iter()
                    .all(|&(_, n)| n == 0));
        assert!(FaultPlan::parse("seed=1;;disk_read;").is_ok());
    }

    #[test]
    fn debug_is_compact() {
        let p = FaultPlan::parse("seed=2;doc_prefill:count=1").unwrap();
        let d = format!("{p:?}");
        assert!(d.contains("doc_prefill"), "{d}");
        assert!(d.contains("seed: 2"), "{d}");
    }
}
