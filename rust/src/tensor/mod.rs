//! Host tensor substrate: contiguous row-major f32/i32 arrays with shape.
//!
//! Purposefully minimal — just what the coordinator needs to shuttle KV
//! caches between PJRT literals and the sparse-selection math. Heavy
//! compute belongs in the AOT artifacts, not here.

mod ops;

pub use ops::{cosine, dot, l2_norm, mean, powerlaw_fit, std_dev};

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Byte size of the payload (memory accounting).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Flat offset of a multi-index.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {x} out of dim {d} (axis {i})");
            off = off * d + x;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// Contiguous sub-slice holding `idx` as a prefix of the full index.
    /// E.g. for shape [L,2,H,S,Dh], `slice_at(&[l,0,h])` is the [S,Dh] row
    /// block.
    pub fn slice_at(&self, idx: &[usize]) -> &[f32] {
        let tail: usize = self.shape[idx.len()..].iter().product();
        let mut off = 0;
        for (&x, &d) in idx.iter().zip(&self.shape) {
            off = off * d + x;
        }
        &self.data[off * tail..(off + 1) * tail]
    }

    pub fn slice_at_mut(&mut self, idx: &[usize]) -> &mut [f32] {
        let tail: usize = self.shape[idx.len()..].iter().product();
        let mut off = 0;
        for (&x, &d) in idx.iter().zip(&self.shape) {
            off = off * d + x;
        }
        &mut self.data[off * tail..(off + 1) * tail]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

/// Row-major i32 tensor (token ids, positions, masks fed to artifacts).
#[derive(Debug, Clone, PartialEq)]
pub struct ITensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl ITensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(ITensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        ITensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn scalar(v: i32) -> Self {
        ITensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<i32>) -> Self {
        ITensor { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect())
            .unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn slice_at_views() {
        // shape [2,2,3]: slice_at(&[1]) is the second [2,3] block
        let t = Tensor::new(vec![2, 2, 3], (0..12).map(|x| x as f32).collect())
            .unwrap();
        assert_eq!(t.slice_at(&[1]), &[6., 7., 8., 9., 10., 11.]);
        assert_eq!(t.slice_at(&[0, 1]), &[3., 4., 5.]);
    }

    #[test]
    fn set_and_mutate() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.set(&[1, 1], 7.0);
        assert_eq!(t.at(&[1, 1]), 7.0);
        t.slice_at_mut(&[0]).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(t.data(), &[1.0, 2.0, 0.0, 7.0]);
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(&[4, 2]);
        assert_eq!(t.clone().reshape(vec![2, 4]).unwrap().shape(), &[2, 4]);
        assert!(t.reshape(vec![3, 3]).is_err());
    }
}
