//! Small numeric helpers used by the sparse-selection pipeline and the
//! Appendix-A attention analytics.

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity (0 when either vector is ~zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na < 1e-12 || nb < 1e-12 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

pub fn mean(a: &[f32]) -> f32 {
    if a.is_empty() {
        0.0
    } else {
        a.iter().sum::<f32>() / a.len() as f32
    }
}

pub fn std_dev(a: &[f32]) -> f32 {
    if a.len() < 2 {
        return 0.0;
    }
    let m = mean(a);
    (a.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / a.len() as f32).sqrt()
}

/// Least-squares power-law fit `y ≈ c · x^(-alpha)` over positive samples
/// (log-log linear regression). Returns `(alpha, log_c)`; alpha > 0 means
/// decaying attention (Fig. 7: smaller alpha = stronger overall attention).
///
/// `ys[i]` is the sample at x = i+1. Non-positive samples are clamped to
/// `eps` (attention probabilities can underflow to 0).
pub fn powerlaw_fit(ys: &[f32]) -> (f32, f32) {
    let eps = 1e-9f32;
    let n = ys.len();
    if n < 2 {
        return (0.0, ys.first().map(|y| y.max(eps).ln()).unwrap_or(0.0));
    }
    let mut sx = 0.0f64;
    let mut sy = 0.0f64;
    let mut sxx = 0.0f64;
    let mut sxy = 0.0f64;
    for (i, &y) in ys.iter().enumerate() {
        let x = ((i + 1) as f64).ln();
        let yl = (y.max(eps) as f64).ln();
        sx += x;
        sy += yl;
        sxx += x * x;
        sxy += x * yl;
    }
    let nf = n as f64;
    let denom = nf * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, (sy / nf) as f32);
    }
    let slope = (nf * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / nf;
    ((-slope) as f32, intercept as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_cosine() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-2.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0)
            .abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn powerlaw_recovers_exponent() {
        // y = 3 * x^-1.7 exactly
        let ys: Vec<f32> = (1..=64)
            .map(|x| 3.0 * (x as f32).powf(-1.7))
            .collect();
        let (alpha, log_c) = powerlaw_fit(&ys);
        assert!((alpha - 1.7).abs() < 1e-3, "alpha = {alpha}");
        assert!((log_c - 3.0f32.ln()).abs() < 1e-3);
    }

    #[test]
    fn powerlaw_orders_attention_strength() {
        // paper Fig. 7: lower alpha <=> higher sustained attention
        let strong: Vec<f32> = (1..=32).map(|x| (x as f32).powf(-0.5)).collect();
        let weak: Vec<f32> = (1..=32).map(|x| (x as f32).powf(-2.5)).collect();
        let (a_strong, _) = powerlaw_fit(&strong);
        let (a_weak, _) = powerlaw_fit(&weak);
        assert!(a_strong < a_weak);
    }

    #[test]
    fn powerlaw_handles_zeros() {
        let ys = vec![0.5, 0.0, 0.0, 0.0];
        let (alpha, _) = powerlaw_fit(&ys);
        assert!(alpha.is_finite() && alpha > 0.0);
    }
}
