//! Recursive-descent JSON parser (strict; trailing content is an error).

use super::Value;
use anyhow::{anyhow, bail, Result};

pub fn parse(src: &str) -> Result<Value> {
    let bytes = src.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected `{}` at byte {}, got `{}`", b as char,
                  self.pos - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            members.push((key, v));
            self.ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(members)),
                c => bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(items)),
                c => bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let cp = if (0xd800..0xdc00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xdc00..0xe000).contains(&lo) {
                                bail!("invalid low surrogate");
                            }
                            0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad codepoint"))?,
                        );
                    }
                    c => bail!("bad escape `\\{}`", c as char),
                },
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = utf8_len(c)?;
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump()?;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow!("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| anyhow!("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = s
            .parse()
            .map_err(|_| anyhow!("invalid number `{s}` at byte {start}"))?;
        Ok(Value::Num(n))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => anyhow::bail!("invalid UTF-8 lead byte"),
    }
}
