//! Minimal JSON substrate (the offline image has no serde).
//!
//! A recursive-descent parser and compact serializer over a small
//! [`Value`] enum. Object member order is preserved (`Vec` of pairs) so
//! emitted manifests diff cleanly. Supports everything the repo's data
//! files use: nested objects/arrays, numbers (parsed as f64), strings
//! with `\uXXXX` escapes, booleans, null.

mod parse;

pub use parse::parse;

use std::fmt;

/// A parsed JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object member lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => {
                members.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> crate::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn members(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(members) => Some(members),
            _ => None,
        }
    }

    /// Convenience: array of usize (shapes, token ids...).
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn i32_vec(&self) -> Option<Vec<i32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32))
            .collect()
    }

    // ---- builders ------------------------------------------------------
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(ref mut members) = self {
            members.push((key.to_string(), v.into()));
        }
        self
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(items: Vec<Value>) -> Self {
        Value::Arr(items)
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if !n.is_finite() {
                // NaN/±inf are not JSON; emit null (as JSON.stringify
                // does) so emitted artifacts stay parseable
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(members) => {
            out.push('{');
            for (i, (k, item)) in members.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(item, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null},
                      "e": true, "f": false}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(),
                   Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(),
                   Some("x\ny"));
        assert_eq!(v.get("e").unwrap().as_bool(), Some(true));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé 😀"));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // regression: `{n}` formatting printed literal NaN/inf tokens,
        // producing unparseable artifacts (e.g. an empty-histogram
        // percentile leaking into BENCH_serving.json)
        let v = Value::obj()
            .set("nan", f64::NAN)
            .set("pinf", f64::INFINITY)
            .set("ninf", f64::NEG_INFINITY)
            .set("ok", 1.5);
        let s = v.to_string();
        assert_eq!(s, r#"{"nan":null,"pinf":null,"ninf":null,"ok":1.5}"#);
        assert!(parse(&s).is_ok(), "{s}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn builders_and_display() {
        let v = Value::obj()
            .set("name", "samkv")
            .set("n", 15usize)
            .set("ok", true)
            .set("xs", Value::Arr(vec![1usize.into(), 2usize.into()]));
        let s = v.to_string();
        assert_eq!(s, r#"{"name":"samkv","n":15,"ok":true,"xs":[1,2]}"#);
    }

    #[test]
    fn usize_vec_helper() {
        let v = parse("[4, 2, 4, 128, 24]").unwrap();
        assert_eq!(v.usize_vec(), Some(vec![4, 2, 4, 128, 24]));
        assert_eq!(parse("[1.5]").unwrap().usize_vec(), None);
    }

    #[test]
    fn req_errors_name_the_key() {
        let v = parse("{}").unwrap();
        let err = v.req("profile").unwrap_err().to_string();
        assert!(err.contains("profile"));
    }
}
