//! Synthetic-task tokenizer: mirrors `python/compile/taskspec.py`.
//!
//! The vocabulary is fixed (256 ids): specials, ordinals, keys, values,
//! fillers. Provides id<->name mapping for logs/examples and the token
//! classification the eval harness and workload generator need.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const QUERY: i32 = 2;
pub const ANS: i32 = 3;
pub const EOS: i32 = 4;
pub const NOORD: i32 = 5;
pub const ORD_BASE: i32 = 6;
pub const MAX_ORD: i32 = 8;

pub const KEY_BASE: i32 = 16;
pub const N_KEYS: i32 = 64;
pub const VAL_BASE: i32 = 80;
pub const N_VALS: i32 = 64;
pub const FILLER_BASE: i32 = 144;
pub const N_FILLERS: i32 = 112;
pub const VOCAB: i32 = 256;

pub const QUERY_LEN: usize = 5;
pub const ANSWER_MAX: usize = 4;

pub fn key_tok(i: i32) -> i32 {
    debug_assert!((0..N_KEYS).contains(&i));
    KEY_BASE + i
}

pub fn val_tok(i: i32) -> i32 {
    debug_assert!((0..N_VALS).contains(&i));
    VAL_BASE + i
}

pub fn filler_tok(i: i32) -> i32 {
    debug_assert!((0..N_FILLERS).contains(&i));
    FILLER_BASE + i
}

/// 1-based ordinal token.
pub fn ord_tok(i: i32) -> i32 {
    debug_assert!((1..=MAX_ORD).contains(&i));
    ORD_BASE + i - 1
}

pub fn is_key(tok: i32) -> bool {
    (KEY_BASE..KEY_BASE + N_KEYS).contains(&tok)
}

pub fn is_value(tok: i32) -> bool {
    (VAL_BASE..VAL_BASE + N_VALS).contains(&tok)
}

pub fn is_filler(tok: i32) -> bool {
    (FILLER_BASE..FILLER_BASE + N_FILLERS).contains(&tok)
}

pub fn is_special(tok: i32) -> bool {
    (0..KEY_BASE).contains(&tok)
}

/// Human-readable token name (for logs and the examples).
pub fn name(tok: i32) -> String {
    match tok {
        PAD => "<pad>".into(),
        BOS => "<bos>".into(),
        QUERY => "<query>".into(),
        ANS => "<ans>".into(),
        EOS => "<eos>".into(),
        NOORD => "<noord>".into(),
        t if (ORD_BASE..ORD_BASE + MAX_ORD).contains(&t) => {
            format!("<ord{}>", t - ORD_BASE + 1)
        }
        t if is_key(t) => format!("K{}", t - KEY_BASE),
        t if is_value(t) => format!("V{}", t - VAL_BASE),
        t if is_filler(t) => format!("f{}", t - FILLER_BASE),
        t => format!("<unk:{t}>"),
    }
}

/// Render a token sequence for display.
pub fn render(toks: &[i32]) -> String {
    toks.iter().map(|&t| name(t)).collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_disjoint_and_cover() {
        for t in 0..VOCAB {
            let classes = [is_key(t), is_value(t), is_filler(t),
                           is_special(t)];
            let n = classes.iter().filter(|&&b| b).count();
            // reserved ids 14..15 are special-range; everything else exactly 1
            assert!(n <= 1 || (is_special(t) && n == 1), "tok {t}");
        }
        assert!(is_key(key_tok(0)) && is_key(key_tok(63)));
        assert!(is_value(val_tok(0)) && is_value(val_tok(63)));
        assert!(is_filler(filler_tok(0)) && is_filler(filler_tok(111)));
    }

    #[test]
    fn names_roundtrip_meaning() {
        assert_eq!(name(BOS), "<bos>");
        assert_eq!(name(key_tok(12)), "K12");
        assert_eq!(name(val_tok(5)), "V5");
        assert_eq!(name(ord_tok(2)), "<ord2>");
        assert_eq!(render(&[QUERY, NOORD, key_tok(1), PAD, ANS]),
                   "<query> <noord> K1 <pad> <ans>");
    }

    #[test]
    fn ordinals() {
        assert_eq!(ord_tok(1), ORD_BASE);
        assert_eq!(ord_tok(8), ORD_BASE + 7);
    }
}
