//! # SamKV — sparse attention across multiple-context KV cache
//!
//! Rust implementation of the AAAI 2026 paper's serving system: a
//! coordinator that manages independently-prefilled per-document KV
//! caches, sparsifies them with personalized per-document query vectors
//! (Eq. 1), anchor-based dynamic Top-P selection (Eq. 2/3), and locally
//! recomputes the sparsified tokens with cross-layer alignment (Fig. 5)
//! and overwrite/fusion write-back (Eq. 4).
//!
//! Compute runs in AOT-compiled XLA artifacts (JAX + Pallas, lowered at
//! build time to HLO text) executed through the PJRT C API — Python is
//! never on the request path. See `DESIGN.md` for the architecture and
//! the per-table/figure experiment index.
//!
//! Module groups:
//! * substrates — [`json`], [`tensor`], [`rng`], [`cli`], [`logging`],
//!   [`exec`], [`bench`], [`faultinject`] (the offline image ships no
//!   serde/clap/tokio/criterion, so these are built from scratch);
//! * runtime — [`runtime`] (PJRT), [`model`] (entry-point wrappers);
//! * paper core — [`kvcache`], [`attention`], [`sparse`], [`policies`];
//! * serving — [`coordinator`], [`server`], [`metrics`], [`eval`],
//!   [`workload`], [`tokenizer`], [`config`].
//!
//! Concurrency tooling: every lock/condvar in the serving stack goes
//! through the [`sync`] facade — `std::sync` in normal builds, loom
//! under `--cfg loom` (see `tests/loom_models.rs`), with opt-in
//! lock-order deadlock detection (`SAMKV_LOCKCHECK=1`). The
//! `panic_lint` binary enforces the no-panic policy on the
//! serving-critical module trees.

// `--cfg loom` and the optional `lockcheck` feature are injected by
// CI jobs; they are not declared in every manifest, so the
// unexpected_cfgs lint must not fire on them.
#![allow(unexpected_cfgs)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod exec;
pub mod faultinject;
pub mod json;
pub mod logging;
pub mod rng;
pub mod tensor;
pub mod tokenizer;

pub mod model;
pub mod runtime;
pub mod workload;

pub mod attention;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod metrics;
pub mod policies;
pub mod server;
pub mod sparse;
pub mod sync;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
