//! Panic-path lint over the serving stack.
//!
//! Scans `rust/src/server`, `rust/src/coordinator`, and
//! `rust/src/kvcache` for constructs that can panic at runtime —
//! `.unwrap()`, `.expect(…)`, `panic!`/`unreachable!`/`todo!`/
//! `unimplemented!`, and variable `[i]`-indexing — outside
//! `#[cfg(test)]` regions. A request that panics a serving thread
//! strands every queued client, so the serving trees must degrade
//! through structured errors instead (see the module docs in
//! `kvcache` and `coordinator`).
//!
//! The checked-in allowlist (`rust/lint_allowlist.txt`, lines of
//! `<path> <count>`) is a **ratchet**: a file may never exceed its
//! allowed count (new panic sites are rejected), and when a file
//! drops below its allowed count the lint also fails until the
//! allowlist is shrunk to match — the count can only go down. Run
//! with `--update` to regenerate the allowlist from the current tree
//! after a burn-down.
//!
//! Deliberately non-findings (so the lint stays reviewable without a
//! full parser):
//! * numeric-literal indexing (`x[0]`) — panics are possible but the
//!   site is statically auditable;
//! * range slicing (`x[a..b]`, `x[..]`) — same `[` token, and the
//!   serving trees use it pervasively for tensor views;
//! * macro/attribute/type brackets (`vec![…]`, `#[…]`, `[u8; 4]`) —
//!   only a `[` directly following an identifier, `)`, or `]` counts
//!   as indexing;
//! * `assert!`-family macros — used for construction-time contracts,
//!   not request-path degradation.
//!
//! Usage: `panic_lint [--root DIR] [--update] [--verbose]`
//! (`tools/lint` wraps `cargo run --bin panic_lint`). Exit code 0 on
//! a clean ratchet, 1 on any violation.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// The serving-critical trees, relative to the repo root.
const SCANNED_TREES: [&str; 3] = [
    "rust/src/server",
    "rust/src/coordinator",
    "rust/src/kvcache",
];

const ALLOWLIST: &str = "rust/lint_allowlist.txt";

/// Panicking macros denied outside test regions. (`assert!` stays
/// allowed; see the module docs.)
const DENIED_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];

/// Keywords that may directly precede `[` without it being indexing
/// (`let [a, b] = …`, `if x { … } … in [1, 2]`, `return [0; 4]`, …).
const NON_INDEX_KEYWORDS: [&str; 12] = [
    "let", "in", "if", "else", "match", "return", "mut", "ref", "move",
    "box", "const", "static",
];

#[derive(Debug, Clone, PartialEq, Eq)]
struct Finding {
    line: usize,
    kind: &'static str,
    snippet: String,
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut update = false;
    let mut verbose = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => {
                    eprintln!("--root needs a directory");
                    return ExitCode::FAILURE;
                }
            },
            "--update" => update = true,
            "--verbose" => verbose = true,
            other => {
                eprintln!("unknown flag `{other}` \
                           (expected --root DIR | --update | --verbose)");
                return ExitCode::FAILURE;
            }
        }
    }
    // `tools/lint` runs from `rust/`; accept either level.
    if !root.join(SCANNED_TREES[0]).is_dir()
        && root.join("src/server").is_dir()
    {
        root = match root.join("..").canonicalize() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cannot resolve repo root: {e}");
                return ExitCode::FAILURE;
            }
        };
    }

    let mut counts: BTreeMap<String, Vec<Finding>> = BTreeMap::new();
    for tree in SCANNED_TREES {
        let dir = root.join(tree);
        let mut files = Vec::new();
        if let Err(e) = rs_files(&dir, &mut files) {
            eprintln!("panic_lint: cannot walk {}: {e}", dir.display());
            return ExitCode::FAILURE;
        }
        files.sort();
        for f in files {
            let src = match std::fs::read_to_string(&f) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("panic_lint: read {}: {e}", f.display());
                    return ExitCode::FAILURE;
                }
            };
            let rel = match f.strip_prefix(&root) {
                Ok(p) => p.to_string_lossy().replace('\\', "/"),
                Err(_) => f.to_string_lossy().into_owned(),
            };
            let findings = scan(&src);
            if verbose {
                for fi in &findings {
                    println!("{rel}:{}: {} `{}`",
                             fi.line, fi.kind, fi.snippet);
                }
            }
            if !findings.is_empty() {
                counts.insert(rel, findings);
            }
        }
    }

    let allow_path = root.join(ALLOWLIST);
    if update {
        let mut out = String::from(
            "# panic_lint ratchet: `<path> <count>` of allowed panic \
             sites per file.\n\
             # Counts may only shrink; regenerate with \
             `tools/lint --update`.\n",
        );
        for (path, findings) in &counts {
            let _ = writeln!(out, "{path} {}", findings.len());
        }
        if let Err(e) = std::fs::write(&allow_path, out) {
            eprintln!("panic_lint: write {}: {e}", allow_path.display());
            return ExitCode::FAILURE;
        }
        println!("panic_lint: wrote {} ({} files, {} sites)",
                 allow_path.display(), counts.len(),
                 counts.values().map(Vec::len).sum::<usize>());
        return ExitCode::SUCCESS;
    }

    let allowed = match load_allowlist(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("panic_lint: {}: {e}", allow_path.display());
            return ExitCode::FAILURE;
        }
    };
    let mut failed = false;
    for (path, findings) in &counts {
        let n = findings.len();
        let cap = allowed.get(path.as_str()).copied().unwrap_or(0);
        if n > cap {
            failed = true;
            eprintln!("panic_lint: {path}: {n} panic sites, allowlist \
                       permits {cap} — new panic paths in the serving \
                       stack must degrade through structured errors:");
            for fi in findings {
                eprintln!("  {path}:{}: {} `{}`",
                          fi.line, fi.kind, fi.snippet);
            }
        }
    }
    for (path, &cap) in &allowed {
        let n = counts.get(*path).map_or(0, Vec::len);
        if n < cap {
            failed = true;
            eprintln!("panic_lint: {path}: {n} panic sites but the \
                       allowlist still permits {cap} — ratchet it down \
                       (run `tools/lint --update`)");
        }
    }
    if failed {
        return ExitCode::FAILURE;
    }
    let total: usize = counts.values().map(Vec::len).sum();
    println!("panic_lint: clean ({} allowlisted sites across {} files)",
             total, counts.len());
    ExitCode::SUCCESS
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn load_allowlist(path: &Path)
                  -> Result<BTreeMap<&'static str, usize>, String> {
    // leak the file body: entries borrow from it for the process life
    let body = match std::fs::read_to_string(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(BTreeMap::new());
        }
        Err(e) => return Err(e.to_string()),
    };
    let body: &'static str = Box::leak(body.into_boxed_str());
    let mut map = BTreeMap::new();
    for (ln, line) in body.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (path, count) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: expected `<path> <count>`",
                                   ln + 1))?;
        let count: usize = count.trim().parse().map_err(|_| {
            format!("line {}: bad count `{count}`", ln + 1)
        })?;
        map.insert(path.trim(), count);
    }
    Ok(map)
}

/// Scan one file: blank comments/strings, then walk the text flagging
/// denied constructs outside `#[cfg(test)]` regions.
fn scan(src: &str) -> Vec<Finding> {
    let text = blank_comments_and_strings(src);
    let bytes = text.as_bytes();
    let test_mask = test_region_mask(&text);
    let mut findings = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if test_mask[i] {
            i += 1;
            continue;
        }
        let rest = &text[i..];
        if rest.starts_with(".unwrap()") {
            push(&mut findings, src, i, "unwrap", &text);
            i += ".unwrap()".len();
            continue;
        }
        if rest.starts_with(".expect(") {
            push(&mut findings, src, i, "expect", &text);
            i += ".expect(".len();
            continue;
        }
        if let Some(m) = denied_macro_at(&text, i) {
            push(&mut findings, src, i, m, &text);
            i += m.len();
            continue;
        }
        if bytes[i] == b'[' && is_indexing(&text, i) {
            if let Some(end) = matching_bracket(bytes, i) {
                let inner = &text[i + 1..end];
                if !inner.contains("..") && !is_numeric(inner) {
                    push(&mut findings, src, i, "index", &text);
                }
                // findings inside the brackets (e.g. `a[b[i]]`) are
                // still scanned: only advance past the `[` itself
            }
        }
        i += 1;
    }
    findings
}

/// `panic!`/`unreachable!`/`todo!`/`unimplemented!` invocation at `i`
/// (identifier boundary on the left, `!` on the right).
fn denied_macro_at(text: &str, i: usize) -> Option<&'static str> {
    let bytes = text.as_bytes();
    if i > 0 && is_ident_byte(bytes[i - 1]) {
        return None;
    }
    DENIED_MACROS.iter().copied().find(|m| {
        text[i..].starts_with(m)
            && bytes.get(i + m.len()) == Some(&b'!')
    })
}

/// Is the `[` at `i` an indexing bracket? Only when it directly
/// follows an expression: an identifier (that is not a keyword or
/// lifetime), `)`, or `]`.
fn is_indexing(text: &str, i: usize) -> bool {
    let bytes = text.as_bytes();
    let mut j = i;
    while j > 0 && (bytes[j - 1] == b' ' || bytes[j - 1] == b'\t') {
        j -= 1;
    }
    if j == 0 {
        return false;
    }
    match bytes[j - 1] {
        b')' | b']' => true,
        c if is_ident_byte(c) => {
            let end = j;
            while j > 0 && is_ident_byte(bytes[j - 1]) {
                j -= 1;
            }
            if j > 0 && bytes[j - 1] == b'\'' {
                return false; // lifetime: `&'a [T]`
            }
            let word = &text[j..end];
            !NON_INDEX_KEYWORDS.contains(&word)
                && !word.as_bytes().first()
                        .is_some_and(u8::is_ascii_digit)
        }
        _ => false,
    }
}

fn matching_bracket(bytes: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'[' => depth += 1,
            b']' => {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
            _ => {}
        }
    }
    None
}

/// Pure numeric-literal index (`0`, `12`, `1_000`), possibly padded.
fn is_numeric(inner: &str) -> bool {
    let t = inner.trim();
    !t.is_empty()
        && t.bytes().all(|b| b.is_ascii_digit() || b == b'_')
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn push(findings: &mut Vec<Finding>, src: &str, i: usize,
        kind: &'static str, text: &str) {
    let line = text[..i].bytes().filter(|&b| b == b'\n').count() + 1;
    let snippet = src
        .lines()
        .nth(line - 1)
        .unwrap_or("")
        .trim()
        .chars()
        .take(60)
        .collect();
    findings.push(Finding { line, kind, snippet });
}

/// Byte mask of regions under a `#[cfg(test)]`-gated item (the
/// attribute itself included). Lite parse: after the attribute, the
/// region runs to the matching `}` of the item's first `{` (or to the
/// end of a `;`-terminated item). Handles `#[cfg(all(test, …))]` by
/// looking for a `test` token anywhere inside `#[cfg(…)]`.
fn test_region_mask(text: &str) -> Vec<bool> {
    let bytes = text.as_bytes();
    let mut mask = vec![false; bytes.len()];
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'#'
            && text[i..].starts_with("#[cfg(")
        {
            let Some(attr_end) = matching_bracket(bytes, i + 1) else {
                break;
            };
            let attr = &text[i..=attr_end];
            if has_test_token(attr) {
                let mut j = attr_end + 1;
                // skip further attributes between cfg and the item
                loop {
                    while j < bytes.len()
                        && (bytes[j] as char).is_whitespace()
                    {
                        j += 1;
                    }
                    if j < bytes.len() && bytes[j] == b'#' {
                        match matching_bracket(bytes, j + 1) {
                            Some(e) => j = e + 1,
                            None => break,
                        }
                    } else {
                        break;
                    }
                }
                // the gated item ends at the matching `}` of its first
                // brace, or at a top-level `;` (use/type items)
                let mut depth = 0usize;
                let mut end = j;
                while end < bytes.len() {
                    match bytes[end] {
                        b'{' => depth += 1,
                        b'}' => {
                            depth = depth.saturating_sub(1);
                            if depth == 0 {
                                break;
                            }
                        }
                        b';' if depth == 0 => break,
                        _ => {}
                    }
                    end += 1;
                }
                let end = (end + 1).min(bytes.len());
                for m in &mut mask[i..end] {
                    *m = true;
                }
                i = end;
                continue;
            }
            i = attr_end + 1;
            continue;
        }
        i += 1;
    }
    mask
}

/// `test` as a standalone token inside an attribute body.
fn has_test_token(attr: &str) -> bool {
    let bytes = attr.as_bytes();
    let mut k = 0;
    while let Some(p) = attr[k..].find("test") {
        let s = k + p;
        let left_ok = s == 0 || !is_ident_byte(bytes[s - 1]);
        let right = s + "test".len();
        let right_ok =
            right >= bytes.len() || !is_ident_byte(bytes[right]);
        if left_ok && right_ok {
            return true;
        }
        k = s + 1;
    }
    false
}

/// Replace comment and string *contents* with spaces (newlines kept so
/// line numbers survive). Handles nested block comments, raw strings,
/// and the char-literal/lifetime ambiguity.
fn blank_comments_and_strings(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    let blank = |out: &mut Vec<u8>, b: &[u8], from: usize, to: usize| {
        for &c in &b[from..to] {
            out.push(if c == b'\n' { b'\n' } else { b' ' });
        }
    };
    while i < b.len() {
        // line comment
        if b[i] == b'/' && b.get(i + 1) == Some(&b'/') {
            let end = src[i..]
                .find('\n')
                .map_or(b.len(), |p| i + p);
            blank(&mut out, b, i, end);
            i = end;
            continue;
        }
        // block comment (nested)
        if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, b, i, j);
            i = j;
            continue;
        }
        // raw string r"…" / r#"…"# (b-prefixed too)
        if (b[i] == b'r' || (b[i] == b'b' && b.get(i + 1) == Some(&b'r')))
            && !(i > 0 && is_ident_byte(b[i - 1]))
        {
            let hash_start = if b[i] == b'r' { i + 1 } else { i + 2 };
            let mut h = hash_start;
            while b.get(h) == Some(&b'#') {
                h += 1;
            }
            if b.get(h) == Some(&b'"') {
                let n_hash = h - hash_start;
                let closer_s = format!("\"{}", "#".repeat(n_hash));
                let closer = closer_s.as_bytes();
                let body = h + 1;
                let end = find_bytes(&b[body..], closer)
                    .map_or(b.len(), |p| body + p + closer.len());
                out.extend_from_slice(&b[i..=h]);
                blank(&mut out, b, h + 1, end);
                i = end;
                continue;
            }
        }
        // plain / byte string
        if b[i] == b'"' {
            let mut j = i + 1;
            while j < b.len() {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            out.push(b'"');
            blank(&mut out, b, i + 1, j.min(b.len()));
            i = j;
            continue;
        }
        // char literal vs lifetime
        if b[i] == b'\'' {
            let is_char = match b.get(i + 1) {
                Some(b'\\') => true,
                Some(_) => b.get(i + 2) == Some(&b'\''),
                None => false,
            };
            if is_char {
                let mut j = i + 1;
                if b.get(j) == Some(&b'\\') {
                    j += 2; // escape body
                    // \x41 and \u{…} escapes: run to the closing quote
                    while j < b.len() && b[j] != b'\'' {
                        j += 1;
                    }
                } else {
                    j += 1;
                }
                let end = (j + 1).min(b.len());
                out.push(b'\'');
                blank(&mut out, b, i + 1, end);
                i = end;
                continue;
            }
        }
        out.push(b[i]);
        i += 1;
    }
    // blanking is byte-for-byte, so the text stays valid UTF-8 only if
    // multibyte chars were kept verbatim — they are (only ASCII
    // delimiters trigger blanking, and blanked bytes become spaces)
    String::from_utf8(out).unwrap_or_default()
}

fn find_bytes(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(&'static str, usize)> {
        scan(src).into_iter().map(|f| (f.kind, f.line)).collect()
    }

    #[test]
    fn flags_unwrap_expect_and_macros() {
        let src = "fn f() {\n    x.unwrap();\n    y.expect(\"m\");\n    \
                   panic!(\"boom\");\n    unreachable!();\n}\n";
        assert_eq!(kinds(src),
                   vec![("unwrap", 2), ("expect", 3), ("panic", 4),
                        ("unreachable", 5)]);
    }

    #[test]
    fn strings_and_comments_do_not_count() {
        let src = "fn f() {\n    // x.unwrap()\n    /* panic!() */\n    \
                   let s = \".unwrap()\";\n    let r = r#\"panic!\"#;\n}\n";
        assert_eq!(kinds(src), vec![]);
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = "fn live() { x.unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n    fn t() { \
                   y.unwrap(); panic!(); }\n}\n\
                   fn live2() { z.unwrap(); }\n";
        assert_eq!(kinds(src), vec![("unwrap", 1), ("unwrap", 6)]);
    }

    #[test]
    fn cfg_all_test_and_stacked_attrs_are_skipped() {
        let src = "#[cfg(all(test, not(loom)))]\n#[allow(dead_code)]\n\
                   mod tests { fn t() { x.unwrap(); } }\n\
                   fn live() { y.unwrap(); }\n";
        assert_eq!(kinds(src), vec![("unwrap", 4)]);
    }

    #[test]
    fn variable_indexing_flags_but_literals_and_ranges_pass() {
        let src = "fn f(v: &[u32], i: usize) {\n    let a = v[i];\n    \
                   let b = v[0];\n    let c = &v[1..3];\n    \
                   let d = &v[..];\n    let e = v[i + 1];\n}\n";
        assert_eq!(kinds(src), vec![("index", 2), ("index", 6)]);
    }

    #[test]
    fn non_index_brackets_pass() {
        let src = "#[derive(Debug)]\nstruct S;\n\
                   fn f() -> [u8; 4] {\n    let v = vec![1, 2];\n    \
                   let l: &'static [u8] = &[1];\n    [0; 4]\n}\n";
        assert_eq!(kinds(src), vec![]);
    }

    #[test]
    fn nested_indexing_reports_both() {
        let src = "fn f(a: &[Vec<u32>], i: usize, j: usize) {\n    \
                   let x = a[i][j];\n}\n";
        assert_eq!(kinds(src).len(), 2);
    }

    #[test]
    fn call_and_slice_results_index() {
        let src = "fn f(m: M, i: usize) {\n    g(m)[i];\n    \
                   m.rows()[i];\n}\n";
        assert_eq!(kinds(src), vec![("index", 2), ("index", 3)]);
    }

    #[test]
    fn char_literals_and_lifetimes_survive() {
        let src = "fn f<'a>(x: &'a [u8], i: usize) -> u8 {\n    \
                   let c = 'x';\n    let n = '\\n';\n    x[i]\n}\n";
        assert_eq!(kinds(src), vec![("index", 4)]);
    }

    #[test]
    fn attribute_test_token_requires_word_boundary() {
        assert!(has_test_token("#[cfg(test)]"));
        assert!(has_test_token("#[cfg(all(test, not(loom)))]"));
        assert!(!has_test_token("#[cfg(feature = \"testing\")]"));
        assert!(!has_test_token("#[cfg(attest)]"));
    }

    #[test]
    fn assert_macros_are_not_flagged() {
        let src = "fn f(n: usize) {\n    assert!(n > 0);\n    \
                   assert_eq!(n, 1);\n    debug_assert!(n < 9);\n}\n";
        assert_eq!(kinds(src), vec![]);
    }
}
