//! Logging substrate: leveled, timestamped stderr logger (no `log`/
//! `tracing` impls offline). Levels are process-global.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Level {
    match s {
        "debug" => Level::Debug,
        "warn" => Level::Warn,
        "error" => Level::Error,
        _ => Level::Info,
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), tag, module, msg);
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info, module_path!(),
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug, module_path!(),
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn, module_path!(),
                             format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error, module_path!(),
                             format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_levels() {
        assert_eq!(level_from_str("debug"), Level::Debug);
        assert_eq!(level_from_str("nonsense"), Level::Info);
    }
}
