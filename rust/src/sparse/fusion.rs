//! Eq. 4 — overwrite vs fusion write-back of recomputed KV (§3.3).
//!
//! The recompute artifact returns a merged buffer (fresh values where
//! `rec_mask` was set, cached elsewhere). *Overwrite* keeps it as-is.
//! *Fusion* blends each recomputed vector with its old value using the
//! cosine similarity θ = cos(new, old):
//!
//! ```text
//! KV_new ← θ·KV_new + (1-θ)·KV_old
//! ```
//!
//! θ is computed per (layer, K/V, head, slot) head-dim vector. θ ≈ 0.9
//! in practice (paper's observation), so fusion mostly trusts the fresh
//! cross-attention-aware values while retaining a sliver of the
//! intra-document history.

use crate::config::{ProfileConfig, UpdateStrategy};
use crate::tensor::{cosine, Tensor};

/// Apply the write-back strategy. `kv_old` is the pre-recompute buffer,
/// `kv_new` the artifact output, `mask` the `[L, S]` recompute mask.
pub fn write_back(cfg: &ProfileConfig, kv_old: &Tensor, mut kv_new: Tensor,
                  mask: &Tensor, strategy: UpdateStrategy) -> Tensor {
    if strategy == UpdateStrategy::Overwrite {
        return kv_new;
    }
    let (nl, nh, dh) = (cfg.n_layers, cfg.n_heads, cfg.head_dim);
    let cap = kv_old.shape()[3];
    for l in 0..nl {
        let mrow = mask.slice_at(&[l]);
        for c in 0..2 {
            for h in 0..nh {
                let old = kv_old.slice_at(&[l, c, h]);
                let new = kv_new.slice_at_mut(&[l, c, h]);
                for s in 0..cap {
                    if mrow[s] == 0.0 {
                        continue;
                    }
                    let o = &old[s * dh..(s + 1) * dh];
                    let range = s * dh..(s + 1) * dh;
                    let theta = cosine(&new[range.clone()], o);
                    for (nv, &ov) in
                        new[range].iter_mut().zip(o.iter())
                    {
                        *nv = theta * *nv + (1.0 - theta) * ov;
                    }
                }
            }
        }
    }
    kv_new
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn cfg() -> ProfileConfig {
        let v = json::parse(
            r#"{"name":"t","n_layers":1,"d_model":8,"n_heads":1,
                "head_dim":4,"d_ff":8,"vocab":16,"n_docs":2,"doc_len":8,
                "block_size":4,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":16,"full_len":25,
                "sparse_kv_len":16,"sparse_len":25,"comp_len":16,
                "blocks_per_doc":2}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    fn bufs(cfg: &ProfileConfig) -> (Tensor, Tensor, Tensor) {
        let shape = [cfg.n_layers, 2, cfg.n_heads, 4, cfg.head_dim];
        let old = Tensor::full(&shape, 1.0);
        let new = Tensor::full(&shape, 3.0);
        let mask = Tensor::zeros(&[cfg.n_layers, 4]);
        (old, new, mask)
    }

    #[test]
    fn overwrite_returns_new_unchanged() {
        let c = cfg();
        let (old, new, mut mask) = bufs(&c);
        mask.set(&[0, 1], 1.0);
        let out =
            write_back(&c, &old, new.clone(), &mask, UpdateStrategy::Overwrite);
        assert_eq!(out, new);
    }

    #[test]
    fn fusion_blends_only_masked_slots() {
        let c = cfg();
        let (old, new, mut mask) = bufs(&c);
        mask.set(&[0, 1], 1.0);
        let out = write_back(&c, &old, new, &mask, UpdateStrategy::Fusion);
        // slot 1: old/new are parallel (all-ones direction): theta = 1
        // -> stays 3.0; unmasked slots also stay 3.0 (untouched)
        assert_eq!(out.at(&[0, 0, 0, 1, 0]), 3.0);
        assert_eq!(out.at(&[0, 0, 0, 0, 0]), 3.0);
    }

    #[test]
    fn fusion_interpolates_by_cosine() {
        let c = cfg();
        let shape = [1, 2, 1, 4, 4];
        let mut old = Tensor::zeros(&shape);
        let mut new = Tensor::zeros(&shape);
        // slot 0, K: old = e1*2, new = e0*4 -> theta = 0
        old.set(&[0, 0, 0, 0, 1], 2.0);
        new.set(&[0, 0, 0, 0, 0], 4.0);
        let mut mask = Tensor::zeros(&[1, 4]);
        mask.set(&[0, 0], 1.0);
        let out = write_back(&c, &old, new, &mask, UpdateStrategy::Fusion);
        // theta = cos = 0 -> result = old entirely
        assert_eq!(out.at(&[0, 0, 0, 0, 0]), 0.0);
        assert_eq!(out.at(&[0, 0, 0, 0, 1]), 2.0);
    }

    #[test]
    fn fusion_high_theta_trusts_new() {
        let c = cfg();
        let shape = [1, 2, 1, 4, 4];
        let mut old = Tensor::zeros(&shape);
        let mut new = Tensor::zeros(&shape);
        // nearly-parallel: theta ~ 1 -> mostly new
        for d in 0..4 {
            old.set(&[0, 1, 0, 2, d], 1.0);
            new.set(&[0, 1, 0, 2, d], 2.0);
        }
        old.set(&[0, 1, 0, 2, 3], 1.2);
        let mut mask = Tensor::zeros(&[1, 4]);
        mask.set(&[0, 2], 1.0);
        let out = write_back(&c, &old, new, &mask, UpdateStrategy::Fusion);
        let got = out.at(&[0, 1, 0, 2, 0]);
        assert!(got > 1.9 && got <= 2.0, "got {got}");
    }
}
