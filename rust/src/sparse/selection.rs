//! Eq. 2/3 — anchor-based dynamic Top-P selection of middle KV blocks.
//!
//! Per stable layer n the personalized query Q̂ scores every block via
//! the block-mean-K inner product. With the init/local anchor score
//! `s_anc`, the most-important middle block `s_max`, and the most-
//! unimportant middle block `s_min` (both from the Appendix-A analysis):
//!
//! ```text
//! P^(n) = (s_max - s_anc) / (s_max - s_min)   if s_anc ∈ (s_min, s_max]
//!         0                                    otherwise
//! P     = mean over the stable layers N*                       (Eq. 3)
//! ```
//!
//! `ceil(P · middle_blocks)` middle blocks are then picked by their
//! N*-averaged scores.

use crate::attention::BlockAttention;
use crate::config::ProfileConfig;
use crate::tensor::Tensor;

/// Outcome of Top-P selection for one document.
#[derive(Debug, Clone)]
pub struct DocSelection {
    /// Eq.-3 consolidated selection ratio.
    pub p: f32,
    /// Eq.-2 per-stable-layer ratios (diagnostics / Fig. ablations).
    pub p_per_layer: Vec<f32>,
    /// N*-averaged block scores (all blocks, absolute block index).
    pub scores: Vec<f32>,
    /// Picked middle blocks (absolute indices, sorted ascending).
    pub picked: Vec<usize>,
}

/// Host-side block scoring for layer `l`: `mean_h ⟨Q̂[l,h], K̄_b[l,h]⟩`
/// (the L1 `block_score` kernel computes the same; `offload_scoring`
/// routes there instead).
pub fn block_scores_host(q_hat: &Tensor, kv: &Tensor,
                         cfg: &ProfileConfig, layer: usize) -> Vec<f32> {
    let (nh, dh, bs) = (cfg.n_heads, cfg.head_dim, cfg.block_size);
    let nb = cfg.blocks_per_doc;
    let mut out = vec![0f32; nb];
    for (b, o) in out.iter_mut().enumerate() {
        let mut acc = 0f32;
        for h in 0..nh {
            let q = q_hat.slice_at(&[layer, h]);
            let k = kv.slice_at(&[layer, 0, h]); // [Ld * Dh]
            // block-mean K
            let mut kbar = vec![0f32; dh];
            for t in b * bs..(b + 1) * bs {
                for (d, kb) in kbar.iter_mut().enumerate() {
                    *kb += k[t * dh + d];
                }
            }
            for (qd, kb) in q.iter().zip(&kbar) {
                acc += qd * kb / bs as f32;
            }
        }
        *o = acc / nh as f32;
    }
    out
}

/// Eq. 2 for one layer given per-block scores and the analysis blocks.
pub fn topp_layer(scores: &[f32], cfg: &ProfileConfig,
                  ba: &BlockAttention, layer: usize) -> f32 {
    let anchors: Vec<usize> = (0..cfg.init_blocks)
        .chain(cfg.blocks_per_doc - cfg.local_blocks..cfg.blocks_per_doc)
        .collect();
    let s_anc = anchors.iter().map(|&b| scores[b]).sum::<f32>()
        / anchors.len() as f32;
    let Some(bmax) = ba.max_middle_block(cfg, layer) else { return 0.0 };
    let Some(bmin) = ba.min_middle_block(cfg, layer) else { return 0.0 };
    let s_max = scores[bmax];
    let s_min = scores[bmin];
    if s_anc > s_min && s_anc <= s_max && s_max > s_min {
        ((s_max - s_anc) / (s_max - s_min)).clamp(0.0, 1.0)
    } else {
        0.0
    }
}

/// Full per-document selection: Eq. 2 per stable layer, Eq. 3 average,
/// then pick `ceil(P · middle)` blocks by N*-mean score.
pub fn topp_select(cfg: &ProfileConfig, per_layer_scores: &[Vec<f32>],
                   stable_layers: &[usize], ba: &BlockAttention)
                   -> DocSelection {
    let nb = cfg.blocks_per_doc;
    debug_assert_eq!(per_layer_scores.len(), stable_layers.len());
    let mut p_per_layer = Vec::with_capacity(stable_layers.len());
    let mut mean_scores = vec![0f32; nb];
    for (scores, &l) in per_layer_scores.iter().zip(stable_layers) {
        p_per_layer.push(topp_layer(scores, cfg, ba, l));
        for (m, &s) in mean_scores.iter_mut().zip(scores) {
            *m += s / stable_layers.len() as f32;
        }
    }
    let p = p_per_layer.iter().sum::<f32>() / p_per_layer.len().max(1) as f32;
    let middle: Vec<usize> =
        (cfg.init_blocks..nb - cfg.local_blocks).collect();
    let want = ((p * middle.len() as f32).ceil() as usize).min(middle.len());
    let mut order = middle.clone();
    order.sort_by(|&a, &b| {
        mean_scores[b].partial_cmp(&mean_scores[a]).unwrap()
    });
    let mut picked: Vec<usize> = order.into_iter().take(want).collect();
    picked.sort_unstable();
    DocSelection { p, p_per_layer, scores: mean_scores, picked }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn cfg8() -> ProfileConfig {
        // 8 blocks of 4: blocks 0 init, 7 local, 1..=6 middle
        let v = json::parse(
            r#"{"name":"t","n_layers":2,"d_model":8,"n_heads":1,
                "head_dim":4,"d_ff":8,"vocab":16,"n_docs":2,"doc_len":32,
                "block_size":4,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":4,"stable_layers":2,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":32,"sparse_len":41,"comp_len":16,
                "blocks_per_doc":8}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    fn fake_ba(cfg: &ProfileConfig, bmax: usize, bmin: usize)
               -> BlockAttention {
        let nb = cfg.blocks_per_doc;
        let nl = cfg.n_layers;
        // alpha: bmax lowest, bmin highest; mean_received: bmin lowest
        let mut alpha = vec![vec![1.0f32; nb]; nl];
        let mut mr = vec![vec![0.5f32; nb]; nl];
        for l in 0..nl {
            alpha[l][bmax] = 0.1;
            alpha[l][bmin] = 2.0;
            mr[l][bmin] = 0.01;
        }
        BlockAttention {
            n_layers: nl,
            n_blocks: nb,
            rep_token: vec![vec![0; nb]; nl],
            alpha,
            mean_received: mr,
            importance_rank: vec![(0..nb).collect(); nl],
            outlier_tokens: vec![Vec::new(); nl],
        }
    }

    #[test]
    fn host_scores_prefer_aligned_block() {
        let cfg = cfg8();
        let mut q = Tensor::zeros(&[2, 1, 4]);
        q.slice_at_mut(&[0, 0])[0] = 1.0;
        let mut kv = Tensor::zeros(&[2, 2, 1, 32, 4]);
        // block 3 (tokens 12..16) aligned with q at layer 0
        for t in 12..16 {
            kv.slice_at_mut(&[0, 0, 0])[t * 4] = 2.0;
        }
        let s = block_scores_host(&q, &kv, &cfg, 0);
        assert_eq!(s.len(), 8);
        assert!(s[3] > 1.9 && s[3] > s[2] + 1.0, "{s:?}");
    }

    #[test]
    fn eq2_interpolates_between_min_and_max() {
        let cfg = cfg8();
        let ba = fake_ba(&cfg, 3, 5);
        // scores: max block 3 -> 1.0, min block 5 -> 0.0, anchors 0.25
        let mut scores = vec![0.25f32; 8];
        scores[3] = 1.0;
        scores[5] = 0.0;
        let p = topp_layer(&scores, &cfg, &ba, 0);
        assert!((p - 0.75).abs() < 1e-6, "p = {p}");
        // anchor above max -> 0
        scores[0] = 2.0;
        scores[7] = 2.0;
        assert_eq!(topp_layer(&scores, &cfg, &ba, 0), 0.0);
        // anchor below min -> 0 (outside the interval)
        scores[0] = -1.0;
        scores[7] = -1.0;
        assert_eq!(topp_layer(&scores, &cfg, &ba, 0), 0.0);
    }

    #[test]
    fn eq3_averages_and_picks_top_scored_middle_blocks() {
        let cfg = cfg8();
        let ba = fake_ba(&cfg, 2, 6);
        // layer a: P = (1 - 0.5)/(1 - 0) = 0.5; layer b: 0 (anchor > max)
        let mut sa = vec![0.3f32; 8];
        sa[0] = 0.5; // anchors
        sa[7] = 0.5;
        sa[2] = 1.0;
        sa[6] = 0.0;
        sa[4] = 0.9; // second-best middle
        let mut sb = vec![0.0f32; 8];
        sb[2] = -0.5;
        sb[6] = -1.0;
        let sel = topp_select(&cfg, &[sa, sb], &[0, 1], &ba);
        assert!((sel.p - 0.25).abs() < 1e-6, "p = {}", sel.p);
        // ceil(0.25 * 6 middle) = 2 blocks; mean scores: b4 = 0.45,
        // b2 = 0.25, other middles 0.15 -> picked {2, 4}
        assert_eq!(sel.picked, vec![2, 4]);
        assert_eq!(sel.p_per_layer.len(), 2);
    }

    #[test]
    fn zero_p_picks_nothing() {
        let cfg = cfg8();
        let ba = fake_ba(&cfg, 2, 6);
        let s = vec![1.0f32; 8]; // anchor == max == min -> degenerate
        let sel = topp_select(&cfg, &[s.clone(), s], &[0, 1], &ba);
        assert_eq!(sel.p, 0.0);
        assert!(sel.picked.is_empty());
    }
}
