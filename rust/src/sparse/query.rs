//! Eq. 1 — Personalized Query Embedding.
//!
//! Starting from the generic query vector `Q_que` (incremental prefill
//! of the user query over the compressed init+local cache, mean-pooled
//! per layer/head), each document i receives a bias from the *other*
//! documents' local Q caches, weighted by `|cos(Q_que, Q_doc-j_loc)|`
//! and normalized by `D-1`:
//!
//! ```text
//! Q̂_i = Q_que + 1/(D-1) · Σ_{j≠i} |cos(Q_que, Q_loc_j)| · Q_loc_j
//! ```
//!
//! The absolute cosine keeps the injected bias positively aligned with
//! whatever K-direction `Q_loc_j` retrieves (§3.1), and the 1/(D-1)
//! factor guards the user query against dilution.

use crate::tensor::{cosine, Tensor};

/// Compute Q̂ for every document.
///
/// * `q_que`: `[L, H, Dh]` generic query vector;
/// * `q_locals[j]`: `[L, H, Dh]` local Q cache of document j;
/// * `pers_bias = false` returns plain copies of `Q_que` (ablation row).
pub fn personalized_queries(q_que: &Tensor, q_locals: &[&Tensor],
                            pers_bias: bool) -> Vec<Tensor> {
    let d = q_locals.len();
    let shape = q_que.shape().to_vec();
    debug_assert_eq!(shape.len(), 3);
    let (nl, nh, dh) = (shape[0], shape[1], shape[2]);
    if !pers_bias || d <= 1 {
        return (0..d).map(|_| q_que.clone()).collect();
    }
    let norm = 1.0 / (d as f32 - 1.0);
    (0..d)
        .map(|i| {
            let mut out = q_que.clone();
            for l in 0..nl {
                for h in 0..nh {
                    let base = q_que.slice_at(&[l, h]);
                    // accumulate bias over the *other* docs
                    let mut bias = vec![0f32; dh];
                    for (j, qloc) in q_locals.iter().enumerate() {
                        if j == i {
                            continue;
                        }
                        let v = qloc.slice_at(&[l, h]);
                        let w = cosine(base, v).abs();
                        for (b, &x) in bias.iter_mut().zip(v) {
                            *b += w * x;
                        }
                    }
                    let dst = out.slice_at_mut(&[l, h]);
                    for (o, b) in dst.iter_mut().zip(&bias) {
                        *o += norm * b;
                    }
                }
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vec3(l: usize, h: usize, dh: usize, f: impl Fn(usize) -> f32)
            -> Tensor {
        let mut t = Tensor::zeros(&[l, h, dh]);
        for i in 0..l {
            for j in 0..h {
                let s = t.slice_at_mut(&[i, j]);
                for (k, x) in s.iter_mut().enumerate() {
                    *x = f(k);
                }
            }
        }
        t
    }

    #[test]
    fn no_bias_returns_q_que() {
        let q = vec3(2, 2, 4, |k| k as f32);
        let l1 = vec3(2, 2, 4, |_| 1.0);
        let l2 = vec3(2, 2, 4, |_| 2.0);
        let out = personalized_queries(&q, &[&l1, &l2], false);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], q);
        assert_eq!(out[1], q);
    }

    #[test]
    fn bias_excludes_own_doc_and_weights_by_cos() {
        // q_que = e0; doc0 local = e0 (cos 1), doc1 local = e1 (cos 0)
        let q = vec3(1, 1, 2, |k| if k == 0 { 1.0 } else { 0.0 });
        let l0 = vec3(1, 1, 2, |k| if k == 0 { 2.0 } else { 0.0 });
        let l1 = vec3(1, 1, 2, |k| if k == 1 { 3.0 } else { 0.0 });
        let out = personalized_queries(&q, &[&l0, &l1], true);
        // doc 0's bias comes only from doc 1 (orthogonal => no change)
        assert_eq!(out[0].slice_at(&[0, 0]), &[1.0, 0.0]);
        // doc 1's bias comes from doc 0: |cos|=1, weight 1/(2-1)=1
        assert_eq!(out[1].slice_at(&[0, 0]), &[3.0, 0.0]);
    }

    #[test]
    fn negative_alignment_still_adds_positively_weighted_bias() {
        // anti-aligned local Q: |cos| = 1, bias keeps the *vector* as-is
        let q = vec3(1, 1, 2, |k| if k == 0 { 1.0 } else { 0.0 });
        let l0 = vec3(1, 1, 2, |k| if k == 0 { -1.0 } else { 0.0 });
        let l1 = vec3(1, 1, 2, |_| 0.0);
        let out = personalized_queries(&q, &[&l1, &l0], true);
        // doc 0 biased by doc 1 (= l0): 1 + 1*(-1) = 0
        assert_eq!(out[0].slice_at(&[0, 0]), &[0.0, 0.0]);
    }

    #[test]
    fn dilution_guard_normalizes_by_docs() {
        // 4 docs, three identical aligned biases: each contributes /3
        let q = vec3(1, 1, 1, |_| 1.0);
        let li = vec3(1, 1, 1, |_| 3.0);
        let out =
            personalized_queries(&q, &[&li, &li, &li, &li], true);
        // 1 + (1/3) * 3 docs * |cos|=1 * 3.0 = 1 + 3
        assert_eq!(out[0].slice_at(&[0, 0]), &[4.0]);
    }
}
