//! Fig. 5 — cross-layer recomputation planning over the assembled buffer.
//!
//! Sparse per-layer selections cannot be aligned across layers; the
//! paper pads mismatched positions with blank blocks and applies two
//! rules: (1) a token recomputed at layer n needs its outputs computed
//! through layers 1..n-1, (2) at layer n, recompute where flagged and
//! reuse cached entries elsewhere.
//!
//! Our buffer gives every selected token a slot at *every* layer, so the
//! "blank block" of the paper is exactly a slot whose `rec_mask[l] = 0`:
//! the recompute artifact computes its layer-n output from the cached KV
//! (rule 2's reuse) while fresh KV is produced only where the mask is 1.
//! The plan marks:
//!   * init/local-block slots at every layer (the EPIC-inherited base),
//!   * PauTa outlier tokens of selected middle blocks at the layers
//!     where they are outliers (A.1),
//! and reports the union token count (the paper's recomputation ratio).

use crate::attention::BlockAttention;
use crate::config::ProfileConfig;
use crate::kvcache::{AssembledContext, SlotKind};
use crate::tensor::Tensor;

/// A layer-resolved recomputation plan for one assembled buffer.
#[derive(Debug, Clone)]
pub struct RecomputePlan {
    /// `[L, S]` — 1.0 where the slot's KV is recomputed at that layer.
    pub mask: Tensor,
    /// Slots recomputed at >= 1 layer.
    pub union_tokens: usize,
    /// Per-layer recomputed-slot counts (diagnostics).
    pub per_layer: Vec<usize>,
    /// union_tokens / ctx_len — the paper's recomputation ratio.
    pub recompute_ratio: f64,
}

/// Build the plan. `per_doc_ba[d]` is document d's attention analysis;
/// pass `include_outliers = false` to restrict to init/local (EPIC-like
/// behaviour inside SamKV's sparse buffer).
pub fn build_recompute_plan(cfg: &ProfileConfig, ctx: &AssembledContext,
                            per_doc_ba: &[&BlockAttention],
                            include_outliers: bool) -> RecomputePlan {
    let nl = cfg.n_layers;
    let cap = ctx.capacity();
    let mut mask = Tensor::zeros(&[nl, cap]);
    for blk in &ctx.blocks {
        match blk.kind {
            SlotKind::Init | SlotKind::Local => {
                // recompute whole block at every layer
                for l in 0..nl {
                    let row = mask.slice_at_mut(&[l]);
                    for t in 0..cfg.block_size {
                        row[blk.slot + t] = 1.0;
                    }
                }
            }
            SlotKind::Selected if include_outliers => {
                let ba = per_doc_ba[blk.doc];
                let t0 = blk.block * cfg.block_size;
                let t1 = t0 + cfg.block_size;
                for l in 0..nl {
                    let row = mask.slice_at_mut(&[l]);
                    for &tok in &ba.outlier_tokens[l] {
                        if tok >= t0 && tok < t1 {
                            row[blk.slot + (tok - t0)] = 1.0;
                        }
                    }
                }
            }
            _ => {}
        }
    }
    let mut per_layer = vec![0usize; nl];
    let mut union = vec![false; cap];
    for (l, pl) in per_layer.iter_mut().enumerate() {
        let row = mask.slice_at(&[l]);
        for (s, &m) in row.iter().enumerate() {
            if m > 0.0 {
                *pl += 1;
                union[s] = true;
            }
        }
    }
    let union_tokens = union.iter().filter(|&&u| u).count();
    RecomputePlan {
        mask,
        union_tokens,
        per_layer,
        recompute_ratio: union_tokens as f64 / cfg.ctx_len as f64,
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::json;
    use crate::kvcache::pool::KvBlockPool;
    use crate::kvcache::store::DocEntry;
    use crate::model::Buffer;

    fn cfg() -> ProfileConfig {
        let v = json::parse(
            r#"{"name":"t","n_layers":2,"d_model":8,"n_heads":1,
                "head_dim":4,"d_ff":8,"vocab":16,"n_docs":2,"doc_len":32,
                "block_size":4,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":4,"stable_layers":2,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":16,
                "blocks_per_doc":8}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    fn doc(cfg: &ProfileConfig) -> DocEntry {
        let tokens: Vec<i32> = (0..cfg.doc_len as i32).collect();
        let pool = Arc::new(KvBlockPool::new(7));
        DocEntry::from_parts(
            &pool,
            tokens,
            Tensor::zeros(&[cfg.n_layers, 2, cfg.n_heads, cfg.doc_len,
                            cfg.head_dim]),
            Tensor::zeros(&[1]),
            Tensor::zeros(&[1]),
        )
        .unwrap()
    }

    fn ba_with_outliers(cfg: &ProfileConfig, l0: Vec<usize>,
                        l1: Vec<usize>) -> BlockAttention {
        let nb = cfg.blocks_per_doc;
        BlockAttention {
            n_layers: 2,
            n_blocks: nb,
            rep_token: vec![vec![0; nb]; 2],
            alpha: vec![vec![1.0; nb]; 2],
            mean_received: vec![vec![0.1; nb]; 2],
            importance_rank: vec![(0..nb).collect(); 2],
            outlier_tokens: vec![l0, l1],
        }
    }

    #[test]
    fn init_local_recomputed_everywhere() {
        let c = cfg();
        let d = doc(&c);
        let mut ctx = AssembledContext::new(&c, Buffer::Sparse);
        ctx.append_block(&c, &d, 0, 0, SlotKind::Init).unwrap();
        ctx.append_block(&c, &d, 0, 7, SlotKind::Local).unwrap();
        let ba = ba_with_outliers(&c, vec![], vec![]);
        let plan = build_recompute_plan(&c, &ctx, &[&ba, &ba], true);
        assert_eq!(plan.per_layer, vec![8, 8]);
        assert_eq!(plan.union_tokens, 8);
        assert!((plan.recompute_ratio - 8.0 / 64.0).abs() < 1e-9);
        // masked exactly on the occupied slots
        assert_eq!(plan.mask.at(&[0, 0]), 1.0);
        assert_eq!(plan.mask.at(&[1, 7]), 1.0);
        assert_eq!(plan.mask.at(&[0, 8]), 0.0);
    }

    #[test]
    fn outliers_are_layer_resolved_misaligned() {
        let c = cfg();
        let d = doc(&c);
        let mut ctx = AssembledContext::new(&c, Buffer::Sparse);
        // selected middle block 2 of doc 0 occupies tokens 8..12
        ctx.append_block(&c, &d, 0, 2, SlotKind::Selected).unwrap();
        // layer 0 flags token 9; layer 1 flags token 11 (Fig.-5 misalign)
        let ba = ba_with_outliers(&c, vec![9], vec![11]);
        let plan = build_recompute_plan(&c, &ctx, &[&ba], true);
        assert_eq!(plan.mask.at(&[0, 1]), 1.0); // slot of token 9
        assert_eq!(plan.mask.at(&[0, 3]), 0.0);
        assert_eq!(plan.mask.at(&[1, 3]), 1.0); // slot of token 11
        assert_eq!(plan.mask.at(&[1, 1]), 0.0);
        assert_eq!(plan.per_layer, vec![1, 1]);
        assert_eq!(plan.union_tokens, 2); // union across layers
    }

    #[test]
    fn outliers_outside_selected_blocks_ignored() {
        let c = cfg();
        let d = doc(&c);
        let mut ctx = AssembledContext::new(&c, Buffer::Sparse);
        ctx.append_block(&c, &d, 0, 2, SlotKind::Selected).unwrap();
        // outlier token 20 lives in block 5 which is NOT in the buffer
        let ba = ba_with_outliers(&c, vec![20], vec![]);
        let plan = build_recompute_plan(&c, &ctx, &[&ba], true);
        assert_eq!(plan.union_tokens, 0);
    }

    #[test]
    fn disable_outliers_restricts_to_fixed_blocks() {
        let c = cfg();
        let d = doc(&c);
        let mut ctx = AssembledContext::new(&c, Buffer::Sparse);
        ctx.append_block(&c, &d, 0, 0, SlotKind::Init).unwrap();
        ctx.append_block(&c, &d, 0, 2, SlotKind::Selected).unwrap();
        let ba = ba_with_outliers(&c, vec![9], vec![9]);
        let plan = build_recompute_plan(&c, &ctx, &[&ba], false);
        assert_eq!(plan.union_tokens, 4); // init block only
    }
}
