//! The SamKV sparsification pipeline (§3):
//!
//! * [`query`] — personalized per-document query vectors (Eq. 1);
//! * [`selection`] — anchor-based dynamic Top-P block selection
//!   (Eq. 2 per layer, Eq. 3 across the stable layers N*);
//! * [`crossfilter`] — cross-context normalization + final block filter;
//! * [`alignment`] — cross-layer recomputation planning over the
//!   assembled buffer (Fig. 5 rules);
//! * [`fusion`] — overwrite/fusion write-back (Eq. 4).

pub mod alignment;
pub mod crossfilter;
pub mod fusion;
pub mod query;
pub mod selection;

pub use alignment::{build_recompute_plan, RecomputePlan};
pub use crossfilter::cross_filter;
pub use fusion::write_back;
pub use query::personalized_queries;
pub use selection::{block_scores_host, topp_select, DocSelection};
