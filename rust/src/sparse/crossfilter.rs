//! Cross-context filtering (§3.2 final step): normalize per-document
//! block scores, pool every document's Top-P picks, and keep only the
//! `pooled / D` most critical blocks — so documents compete for the
//! sparse budget instead of each padding it independently.

use crate::config::ProfileConfig;
use crate::tensor::{mean, std_dev};

use super::selection::DocSelection;

/// Final per-document middle-block sets after cross-context filtering.
/// The result is additionally capped at `cfg.sel_cap_blocks` total (the
/// static sparse-buffer capacity).
pub fn cross_filter(cfg: &ProfileConfig, selections: &[DocSelection])
                    -> Vec<Vec<usize>> {
    let d = selections.len();
    let mut pooled: Vec<(usize, usize, f32)> = Vec::new(); // (doc, block, z)
    for (doc, sel) in selections.iter().enumerate() {
        if sel.picked.is_empty() {
            continue;
        }
        // z-normalize this document's scores so documents are comparable
        let m = mean(&sel.scores);
        let s = std_dev(&sel.scores).max(1e-6);
        for &b in &sel.picked {
            pooled.push((doc, b, (sel.scores[b] - m) / s));
        }
    }
    // keep = pooled / D, capped by the buffer budget
    let keep = (pooled.len() / d.max(1))
        .max(usize::from(!pooled.is_empty()))
        .min(cfg.sel_cap_blocks);
    pooled.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    pooled.truncate(keep);
    let mut out = vec![Vec::new(); d];
    for (doc, b, _) in pooled {
        out[doc].push(b);
    }
    for v in out.iter_mut() {
        v.sort_unstable();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn cfg() -> ProfileConfig {
        let v = json::parse(
            r#"{"name":"t","n_layers":2,"d_model":8,"n_heads":1,
                "head_dim":4,"d_ff":8,"vocab":16,"n_docs":4,"doc_len":32,
                "block_size":4,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":4,"stable_layers":2,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":128,"full_len":137,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
                "blocks_per_doc":8}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    fn sel(picked: Vec<usize>, hot: &[(usize, f32)]) -> DocSelection {
        let mut scores = vec![0.0f32; 8];
        for &(b, s) in hot {
            scores[b] = s;
        }
        DocSelection { p: 0.5, p_per_layer: vec![], scores, picked }
    }

    #[test]
    fn keeps_pooled_over_d_blocks() {
        let c = cfg();
        // 4 docs x 2 picks = 8 pooled -> keep 8/4 = 2
        let sels: Vec<DocSelection> = (0..4)
            .map(|i| {
                sel(vec![2, 3],
                    &[(2, 1.0 + i as f32), (3, 0.5 + i as f32)])
            })
            .collect();
        let out = cross_filter(&c, &sels);
        let total: usize = out.iter().map(|v| v.len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn strongest_blocks_survive() {
        let c = cfg();
        // doc 0 picked two blocks: 4 decisively hot (high z), 5 mild;
        // doc 1 picked two close blocks (low z spread); docs 2/3 empty.
        let sels = vec![
            sel(vec![4, 5], &[(4, 10.0), (5, 5.0)]),
            sel(vec![2, 3], &[(2, 1.0), (3, 0.9)]),
            sel(vec![], &[]),
            sel(vec![], &[]),
        ];
        // pooled 4 / D 4 = keep 1 -> doc 0's block 4 (highest z) wins
        let out = cross_filter(&c, &sels);
        let total: usize = out.iter().map(|v| v.len()).sum();
        assert_eq!(total, 1);
        assert_eq!(out[0], vec![4], "{out:?}");
        assert!(out[1].is_empty());
    }

    #[test]
    fn empty_selections_yield_empty() {
        let c = cfg();
        let sels: Vec<DocSelection> =
            (0..4).map(|_| sel(vec![], &[])).collect();
        let out = cross_filter(&c, &sels);
        assert!(out.iter().all(|v| v.is_empty()));
    }

    #[test]
    fn respects_buffer_cap() {
        let c = cfg(); // sel_cap_blocks = 4
        let sels: Vec<DocSelection> = (0..4)
            .map(|_| {
                sel(vec![1, 2, 3, 4, 5, 6],
                    &[(1, 1.), (2, 1.), (3, 1.), (4, 1.), (5, 1.), (6, 1.)])
            })
            .collect();
        let out = cross_filter(&c, &sels);
        let total: usize = out.iter().map(|v| v.len()).sum();
        assert!(total <= 4, "total {total}");
    }
}
