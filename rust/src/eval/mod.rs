//! Evaluation harness: token-level F1 (the paper's metric) over the
//! synthetic LongBench stand-ins, per policy, with the serving
//! measurements aggregated for Tables 1/3/4 and Fig. 1.

use anyhow::Result;

use crate::kvcache::EngineDocCache;
use crate::model::Model;
use crate::policies::ContextPolicy;
use crate::workload::{Dataset, Sample};

/// Token-level F1 between predicted and gold answers (multiset overlap,
/// exactly the LongBench QA scoring applied to token ids).
pub fn token_f1(pred: &[i32], gold: &[i32]) -> f64 {
    if pred.is_empty() || gold.is_empty() {
        return if pred.is_empty() && gold.is_empty() { 1.0 } else { 0.0 };
    }
    let mut gold_counts = std::collections::HashMap::new();
    for &g in gold {
        *gold_counts.entry(g).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for &p in pred {
        if let Some(c) = gold_counts.get_mut(&p) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Aggregated result of one (policy, dataset) evaluation.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub policy: String,
    pub dataset: String,
    pub n: usize,
    /// Mean token F1 × 100 (paper convention).
    pub f1: f64,
    /// Exact-match rate.
    pub em: f64,
    pub mean_ttft_ms: f64,
    pub mean_decode_ms: f64,
    /// Mean pure-planning stage time (staged serving protocol).
    pub mean_plan_ms: f64,
    /// Mean engine-queue wait (zero on this blocking path; populated
    /// when stats come back through the continuous-batching engine).
    pub mean_queue_wait_ms: f64,
    /// Mean document-prefill stage time (near zero: caches pre-warmed).
    pub mean_doc_prefill_ms: f64,
    pub mean_seq_ratio: f64,
    pub mean_recompute_ratio: f64,
    pub mean_kv_bytes: f64,
    /// Fraction of document lookups served from either cache tier
    /// (resident or host) rather than freshly prefilled, over the
    /// whole run including the pre-warm pass (0 for cacheless
    /// policies).
    pub doc_cache_hit_rate: f64,
    /// Host-tier peak footprint over the run, bytes.
    pub doc_cache_peak_bytes: usize,
    /// Per-query-type F1 × 100.
    pub per_type: Vec<(String, f64, usize)>,
}

/// Evaluate a policy over (up to `max_samples` of) a dataset.
///
/// Document caches are pre-warmed before each sample so TTFT reflects
/// the paper's context-caching regime (stored KV, excluded from TTFT);
/// the Recompute baseline ignores the cache by construction.
pub fn evaluate(model: &Model, policy: &dyn ContextPolicy,
                dataset: &Dataset, max_samples: usize)
                -> Result<EvalResult> {
    let mut store = EngineDocCache::unbounded();
    let n = dataset.samples.len().min(max_samples);
    let mut f1_sum = 0.0;
    let mut em_sum = 0.0;
    let mut ttft = 0.0;
    let mut decode = 0.0;
    let mut plan = 0.0;
    let mut queue_wait = 0.0;
    let mut doc_prefill = 0.0;
    let mut seq = 0.0;
    let mut rec = 0.0;
    let mut bytes = 0.0;
    let mut per: std::collections::BTreeMap<String, (f64, usize)> =
        Default::default();
    for sample in &dataset.samples[..n] {
        if policy.uses_doc_cache() {
            for d in &sample.docs {
                store.get_or_prefill(model, d)?;
            }
        }
        let out = policy.run(model, &mut store, sample)?;
        let f1 = token_f1(&out.answer, &sample.answer);
        f1_sum += f1;
        em_sum += f64::from(out.answer == sample.answer);
        ttft += out.stats.ttft_ms;
        decode += out.stats.decode_ms;
        plan += out.stats.plan_ms;
        queue_wait += out.stats.queue_wait_ms;
        doc_prefill += out.stats.doc_prefill_ms;
        seq += out.stats.seq_ratio;
        rec += out.stats.recompute_ratio;
        bytes += out.stats.kv_bytes as f64;
        let e = per.entry(sample.qtype.clone()).or_insert((0.0, 0));
        e.0 += f1;
        e.1 += 1;
        // bound memory: evaluation samples never repeat documents
        // (drop both tiers — the private host tier would otherwise
        // keep every entry alive)
        if store.len() > 64 {
            store.clear_all();
        }
    }
    let nf = n as f64;
    let host = store.host_stats();
    let res = store.stats().clone();
    // every resident-tier miss falls through to the host tier, so the
    // resident counters cover all lookups and host.misses are the true
    // prefills
    let lookups = res.hits + res.misses;
    let tier_hit_rate = if lookups == 0 {
        0.0
    } else {
        (res.hits + host.hits) as f64 / lookups as f64
    };
    Ok(EvalResult {
        policy: policy.name(),
        dataset: dataset.dataset.clone(),
        n,
        f1: 100.0 * f1_sum / nf,
        em: em_sum / nf,
        mean_ttft_ms: ttft / nf,
        mean_decode_ms: decode / nf,
        mean_plan_ms: plan / nf,
        mean_queue_wait_ms: queue_wait / nf,
        mean_doc_prefill_ms: doc_prefill / nf,
        mean_seq_ratio: seq / nf,
        mean_recompute_ratio: rec / nf,
        mean_kv_bytes: bytes / nf,
        doc_cache_hit_rate: tier_hit_rate,
        doc_cache_peak_bytes: host.peak_bytes,
        per_type: per
            .into_iter()
            .map(|(k, (s, c))| (k, 100.0 * s / c as f64, c))
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_exact_match() {
        assert_eq!(token_f1(&[80, 81], &[80, 81]), 1.0);
        assert_eq!(token_f1(&[80], &[80]), 1.0);
    }

    #[test]
    fn f1_no_overlap() {
        assert_eq!(token_f1(&[80], &[81]), 0.0);
        assert_eq!(token_f1(&[], &[81]), 0.0);
        assert_eq!(token_f1(&[80], &[]), 0.0);
    }

    #[test]
    fn f1_partial_credit() {
        // pred {80, 99}, gold {80, 81}: overlap 1, P = R = 0.5 -> F1 0.5
        assert!((token_f1(&[80, 99], &[80, 81]) - 0.5).abs() < 1e-9);
        // pred {80}, gold {80, 81}: P 1, R 0.5 -> F1 2/3
        assert!((token_f1(&[80], &[80, 81]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn f1_multiset_semantics() {
        // duplicate predictions only match as many golds as exist
        assert!((token_f1(&[80, 80], &[80, 81]) - 0.5).abs() < 1e-9);
        assert_eq!(token_f1(&[80, 80], &[80, 80]), 1.0);
    }

    #[test]
    fn f1_order_invariant() {
        assert_eq!(token_f1(&[81, 80], &[80, 81]), 1.0);
    }
}
