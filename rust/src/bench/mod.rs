//! Benchmark substrate (no criterion offline): warmup + timed iterations
//! with percentile stats and markdown table rendering. Every
//! `rust/benches/*.rs` table/figure harness prints through this module so
//! outputs are uniform and parseable.

pub mod experiments;

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl Stats {
    pub fn from_samples(name: &str, samples_ms: &[f64]) -> Stats {
        assert!(!samples_ms.is_empty());
        let mut xs = samples_ms.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[(((n - 1) as f64) * p).round() as usize];
        Stats {
            name: name.to_string(),
            iters: n,
            mean_ms: mean,
            std_ms: var.sqrt(),
            min_ms: xs[0],
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            max_ms: xs[n - 1],
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Stats::from_samples(name, &samples)
}

/// Markdown-ish table printer used by all bench binaries.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:w$} |", c, w = w));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format milliseconds compactly.
pub fn ms(v: f64) -> String {
    if v >= 1000.0 {
        format!("{:.2}s", v / 1000.0)
    } else if v >= 1.0 {
        format!("{v:.1}ms")
    } else {
        format!("{:.0}us", v * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Stats::from_samples("t", &xs);
        assert_eq!(s.iters, 100);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 100.0);
        assert_eq!(s.p50_ms, 51.0); // (n-1)*0.5 = 49.5 rounds up
        assert_eq!(s.p95_ms, 95.0);
        assert_eq!(s.p99_ms, 99.0);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.mean_ms >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "F1"]);
        t.row(vec!["SamKV-fusion".into(), "27.88".into()]);
        t.row(vec!["Reuse".into(), "6.33".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("method"));
        assert!(lines[2].contains("SamKV-fusion"));
        // all lines equal width
        assert_eq!(lines.iter().map(|l| l.len()).collect::<Vec<_>>(),
                   vec![lines[0].len(); 4]);
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(ms(0.5), "500us");
        assert_eq!(ms(12.34), "12.3ms");
        assert_eq!(ms(1500.0), "1.50s");
    }
}
