//! Experiment generators — one per table/figure of the paper's
//! evaluation (see DESIGN.md §3). Each prints the paper-shaped table
//! and returns a JSON object that the bench binaries persist under
//! `artifacts/results/` for EXPERIMENTS.md.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::attention::{analyze_doc, layer_stability_scores};
use crate::bench::Table;
use crate::config::{KvCodecKind, SamKvConfig, UpdateStrategy};
use crate::eval::{evaluate, EvalResult};
use crate::json::Value;
use crate::kvcache::EngineDocCache;
use crate::model::Model;
use crate::policies::{
    all_policies, CacheBlendPolicy, ContextPolicy, EpicPolicy,
    RecomputePolicy, SamKvPolicy,
};
use crate::runtime::{artifacts_dir, Runtime};
use crate::workload::Dataset;

/// Load a profile's model on a fresh runtime.
pub fn load_model(profile: &str) -> Result<Model> {
    let rt = Rc::new(Runtime::new(artifacts_dir())?);
    Model::load(rt, profile)
}

/// Load one of the profile's eval datasets by name.
pub fn load_dataset(model: &Model, name: &str) -> Result<Dataset> {
    let meta = model.runtime().manifest().profile(&model.name)?;
    let rel = meta
        .datasets
        .get(name)
        .with_context(|| format!("dataset `{name}` not in manifest"))?;
    Dataset::load(model.runtime().manifest().path(rel))
}

pub fn dataset_names(model: &Model) -> Vec<String> {
    model
        .runtime()
        .manifest()
        .profile(&model.name)
        .map(|m| m.datasets.keys().cloned().collect())
        .unwrap_or_default()
}

/// Persist an experiment result under `artifacts/results/<name>.json`.
pub fn save_result(name: &str, v: &Value) -> Result<()> {
    let dir = artifacts_dir().join("results");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.json")), v.to_string())?;
    Ok(())
}

fn eval_to_json(r: &EvalResult) -> Value {
    Value::obj()
        .set("policy", r.policy.as_str())
        .set("dataset", r.dataset.as_str())
        .set("n", r.n)
        .set("f1", r.f1)
        .set("em", r.em)
        .set("ttft_ms", r.mean_ttft_ms)
        .set("decode_ms", r.mean_decode_ms)
        .set("plan_ms", r.mean_plan_ms)
        .set("queue_wait_ms", r.mean_queue_wait_ms)
        .set("doc_prefill_ms", r.mean_doc_prefill_ms)
        .set("seq_ratio", r.mean_seq_ratio)
        .set("recompute_ratio", r.mean_recompute_ratio)
        .set("kv_bytes", r.mean_kv_bytes)
        .set("doc_cache_hit_rate", r.doc_cache_hit_rate)
        .set("doc_cache_peak_bytes", r.doc_cache_peak_bytes)
}

// ---------------------------------------------------------------------------
// Table 1 — sequence ratio & recomputation ratio per multi-context method
// ---------------------------------------------------------------------------

pub fn table1(model: &Model, dataset: &Dataset, n: usize) -> Result<Value> {
    println!("== Table 1: sequence / recomputation ratios \
              (model {}, {} x{})\n", model.name, dataset.dataset, n);
    let policies: Vec<Box<dyn ContextPolicy>> = vec![
        Box::new(CacheBlendPolicy::default()),
        Box::new(EpicPolicy::default()),
        Box::new(SamKvPolicy::new(SamKvConfig::default())),
    ];
    let mut tbl = Table::new(&["Multi-context method", "Sequence ratio",
                               "Recomputation ratio"]);
    let mut rows = Vec::new();
    for p in &policies {
        let r = evaluate(model, p.as_ref(), dataset, n)?;
        tbl.row(vec![
            r.policy.clone(),
            format!("{:.1}%", 100.0 * r.mean_seq_ratio),
            format!("{:.1}%", 100.0 * r.mean_recompute_ratio),
        ]);
        rows.push(eval_to_json(&r));
    }
    tbl.print();
    let v = Value::obj()
        .set("experiment", "table1")
        .set("model", model.name.as_str())
        .set("dataset", dataset.dataset.as_str())
        .set("rows", Value::Arr(rows));
    save_result(&format!("table1_{}", model.name), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Fig. 1 — TTFT (% of full recompute) vs F1, with KV memory
// ---------------------------------------------------------------------------

/// `100 * value / base`, guarded so an empty or degenerate baseline
/// row (`base == 0`, e.g. a zero-sample recompute run) yields a finite
/// ratio instead of NaN/inf leaking into the persisted JSON.
pub fn ratio_pct(value: f64, base: f64) -> f64 {
    100.0 * value / base.max(1e-9)
}

pub fn fig1(model: &Model, dataset: &Dataset, n: usize) -> Result<Value> {
    println!("== Fig. 1: TTFT%% vs F1 vs KV memory \
              (model {}, {} x{})\n", model.name, dataset.dataset, n);
    let recompute = evaluate(model, &RecomputePolicy, dataset, n)?;
    let base_ttft = recompute.mean_ttft_ms;
    let mut tbl = Table::new(&["method", "TTFT (% of recompute)", "F1",
                               "KV memory (KiB)"]);
    let mut rows = Vec::new();
    for p in all_policies() {
        let r = if p.name() == "Recompute" {
            recompute.clone()
        } else {
            evaluate(model, p.as_ref(), dataset, n)?
        };
        tbl.row(vec![
            r.policy.clone(),
            format!("{:.0}%", ratio_pct(r.mean_ttft_ms, base_ttft)),
            format!("{:.2}", r.f1),
            format!("{:.0}", r.mean_kv_bytes / 1024.0),
        ]);
        rows.push(eval_to_json(&r)
            .set("ttft_pct", ratio_pct(r.mean_ttft_ms, base_ttft)));
    }
    tbl.print();
    let v = Value::obj()
        .set("experiment", "fig1")
        .set("model", model.name.as_str())
        .set("dataset", dataset.dataset.as_str())
        .set("rows", Value::Arr(rows));
    save_result(&format!("fig1_{}", model.name), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Table 3 — F1 of every method across the QA datasets
// ---------------------------------------------------------------------------

const TABLE3_DATASETS: [&str; 3] =
    ["wiki2-sim", "musique-sim", "hotpot-sim"];

pub fn table3(model: &Model, n: usize) -> Result<Value> {
    println!("== Table 3: F1 across methods (model {}, n={})\n",
             model.name, n);
    let datasets: Vec<Dataset> = TABLE3_DATASETS
        .iter()
        .map(|d| load_dataset(model, d))
        .collect::<Result<_>>()?;
    let mut headers = vec!["Method".to_string()];
    headers.extend(TABLE3_DATASETS.iter().map(|s| s.to_string()));
    let mut tbl =
        Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    let mut baseline: Vec<f64> = Vec::new();
    for p in all_policies() {
        let mut cells = vec![p.name()];
        let mut row = Value::obj().set("policy", p.name());
        let mut f1s = Vec::new();
        for ds in &datasets {
            let r = evaluate(model, p.as_ref(), ds, n)?;
            let delta = if baseline.len() < TABLE3_DATASETS.len() {
                String::new()
            } else {
                format!(" ({:+.2})", r.f1 - baseline[f1s.len()])
            };
            cells.push(format!("{:.2}{}", r.f1, delta));
            row = row.set(ds.dataset.as_str(), eval_to_json(&r));
            f1s.push(r.f1);
        }
        if baseline.is_empty() {
            baseline = f1s.clone();
        }
        tbl.row(cells);
        rows.push(row);
    }
    tbl.print();
    let v = Value::obj()
        .set("experiment", "table3")
        .set("model", model.name.as_str())
        .set("n", n)
        .set("rows", Value::Arr(rows));
    save_result(&format!("table3_{}", model.name), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Table 4 — ablations: selection x personalized bias x recomputation
// ---------------------------------------------------------------------------

const TABLE4_DATASETS: [&str; 4] =
    ["wiki2-sim", "musique-sim", "hotpot-sim", "dureader-sim"];

pub fn table4(model: &Model, n: usize) -> Result<Value> {
    println!("== Table 4: SamKV ablations (model {}, n={}, fusion)\n",
             model.name, n);
    let datasets: Vec<Dataset> = TABLE4_DATASETS
        .iter()
        .map(|d| load_dataset(model, d))
        .collect::<Result<_>>()?;
    // (label, selection, pers_bias, recompute); None = Recompute baseline
    let variants: [(&str, Option<(bool, bool, bool)>); 7] = [
        ("Recompute", None),
        ("sel=x rec=x", Some((false, false, false))),
        ("sel=x rec=ok", Some((false, false, true))),
        ("sel=ok pb=x rec=x", Some((true, false, false))),
        ("sel=ok pb=ok rec=x", Some((true, true, false))),
        ("sel=ok pb=x rec=ok", Some((true, false, true))),
        ("sel=ok pb=ok rec=ok", Some((true, true, true))),
    ];
    let mut headers = vec!["Variant".to_string()];
    headers.extend(TABLE4_DATASETS.iter().map(|s| s.to_string()));
    headers.push("Avg.".to_string());
    let mut tbl =
        Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for (label, flags) in variants {
        let policy: Box<dyn ContextPolicy> = match flags {
            None => Box::new(RecomputePolicy),
            Some((sel, pb, rec)) => Box::new(SamKvPolicy::new(SamKvConfig {
                selection: sel,
                pers_bias: pb,
                recompute: rec,
                update: UpdateStrategy::Fusion,
                ..SamKvConfig::default()
            })),
        };
        let mut cells = vec![label.to_string()];
        let mut row = Value::obj().set("variant", label);
        let mut sum = 0.0;
        for ds in &datasets {
            let r = evaluate(model, policy.as_ref(), ds, n)?;
            cells.push(format!("{:.2}", r.f1));
            sum += r.f1;
            row = row.set(ds.dataset.as_str(), eval_to_json(&r));
        }
        let avg = sum / datasets.len() as f64;
        cells.push(format!("{avg:.2}"));
        row = row.set("avg", avg);
        tbl.row(cells);
        rows.push(row);
    }
    tbl.print();
    let v = Value::obj()
        .set("experiment", "table4")
        .set("model", model.name.as_str())
        .set("n", n)
        .set("rows", Value::Arr(rows));
    save_result(&format!("table4_{}", model.name), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Fig. 7 — power-law block attention analysis
// ---------------------------------------------------------------------------

pub fn fig7(model: &Model, dataset: &Dataset, n_docs: usize)
            -> Result<Value> {
    println!("== Fig. 7: block power-law fits (model {}, {} docs)\n",
             model.name, n_docs);
    let cfg = model.cfg.clone();
    let mut store = EngineDocCache::unbounded();
    let mut alphas_all = Vec::new();
    let mut tbl = Table::new(&["doc", "block", "rep tok", "alpha",
                               "mean recv", "imp rank"]);
    let mut count = 0usize;
    'outer: for sample in &dataset.samples {
        for doc in &sample.docs {
            let (e, _) = store.get_or_prefill(model, doc)?;
            let ba = analyze_doc(&e.attn, &cfg, 3.0);
            let l = cfg.n_layers - 1;
            for b in 0..cfg.blocks_per_doc {
                if count == 0 {
                    tbl.row(vec![
                        format!("{count}"),
                        format!("{b}"),
                        format!("{}", ba.rep_token[l][b]),
                        format!("{:.3}", ba.alpha[l][b]),
                        format!("{:.4}", ba.mean_received[l][b]),
                        format!("{}", ba.importance_rank[l][b]),
                    ]);
                }
                if ba.alpha[l][b].is_finite() {
                    alphas_all.push(ba.alpha[l][b] as f64);
                }
            }
            count += 1;
            if count >= n_docs {
                break 'outer;
            }
        }
    }
    tbl.print();
    alphas_all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = alphas_all.iter().sum::<f64>() / alphas_all.len() as f64;
    let med = alphas_all[alphas_all.len() / 2];
    println!("alpha over {} blocks: mean {:.3}, median {:.3}, min {:.3}, \
              max {:.3}", alphas_all.len(), mean, med,
             alphas_all[0], alphas_all[alphas_all.len() - 1]);
    println!("(paper Fig. 7: smaller alpha = stronger sustained attention; \
              ordering of fits defines block importance)");
    let v = Value::obj()
        .set("experiment", "fig7")
        .set("model", model.name.as_str())
        .set("n_blocks", alphas_all.len())
        .set("alpha_mean", mean)
        .set("alpha_median", med)
        .set("alpha_min", alphas_all[0])
        .set("alpha_max", alphas_all[alphas_all.len() - 1]);
    save_result(&format!("fig7_{}", model.name), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Fig. 8 — per-layer attention-stability scores per dataset
// ---------------------------------------------------------------------------

pub fn fig8(model: &Model, n_docs: usize) -> Result<Value> {
    println!("== Fig. 8: layer stability scores (model {}, {} docs per \
              dataset)\n", model.name, n_docs);
    let cfg = model.cfg.clone();
    let mut headers = vec!["dataset".to_string()];
    headers.extend((0..cfg.n_layers).map(|l| format!("L{l}")));
    let mut tbl =
        Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut out_rows = Vec::new();
    for ds_name in dataset_names(model) {
        let ds = load_dataset(model, &ds_name)?;
        let mut store = EngineDocCache::unbounded();
        let mut analyses = Vec::new();
        let mut count = 0;
        'outer: for sample in &ds.samples {
            for doc in &sample.docs {
                let (e, _) = store.get_or_prefill(model, doc)?;
                analyses.push(analyze_doc(&e.attn, &cfg, 3.0));
                count += 1;
                if count >= n_docs {
                    break 'outer;
                }
            }
        }
        let refs: Vec<_> = analyses.iter().collect();
        let scores = layer_stability_scores(&refs, 1.5);
        let mut cells = vec![ds_name.clone()];
        cells.extend(scores.iter().map(|s| format!("{s:.2}")));
        tbl.row(cells);
        out_rows.push(Value::obj().set("dataset", ds_name.as_str()).set(
            "scores",
            Value::Arr(scores.iter().map(|&s| (s as f64).into()).collect()),
        ));
    }
    tbl.print();
    println!("(N* = trailing high-stability layers; serving uses the last \
              {} layers)", cfg.stable_layers);
    let v = Value::obj()
        .set("experiment", "fig8")
        .set("model", model.name.as_str())
        .set("rows", Value::Arr(out_rows));
    save_result(&format!("fig8_{}", model.name), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Serving throughput/latency under load (system experiment)
// ---------------------------------------------------------------------------

/// Parse a `--batch-sizes`/`--rates`-style CSV flag value (shared by
/// the bench binary and the CLI subcommand so their defaults cannot
/// drift). Errors on any unparsable entry rather than silently
/// shrinking the sweep grid.
pub fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::error::Error + Send + Sync + 'static,
{
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<T>()
                .with_context(|| format!("bad list entry `{x}`"))
        })
        .collect()
}

/// One serving-throughput run: drive the continuous-batching engines
/// (persistent decode scheduler + mid-round admission over one shared
/// host doc-cache tier + cache-aware router + metrics) with a
/// synthetic load where document sets recur (`n_unique` distinct sets
/// across `n_requests`) and requests arrive at `arrival_rps` requests
/// per second (0 = submit as fast as possible). With `disk_dir` set,
/// a persistent write-through disk tier is attached beneath the host
/// tier — running twice over the same directory measures a warm
/// restart (zero model prefills, documents served off disk). Returns
/// the per-run JSON row: tokens/sec, TTFT and queue-wait percentiles,
/// fused and batched decode-round counters (executions per round,
/// lane occupancy, admission/decode overlap), the per-tier cache
/// behaviour, the KV block-pool counters (`pool_*`: slot gauges
/// plus share-hit / partial-eviction events), the codec counters
/// (`codec_*`, under the `codec`/`hot_blocks` the cache stack was
/// built with), and `answers_fnv` — an FNV-1a digest of every
/// response's tokens in request-id order, so two runs over the same
/// workload can be compared for token-identical output. With
/// `n_engines >= 2` the host-tier publish counter
/// proves the cross-engine dedup: each unique document is prefilled
/// exactly once process-wide.
pub fn throughput_run(profile: &str, policy: &str, n_requests: usize,
                      n_unique: usize, n_engines: usize, max_batch: usize,
                      arrival_rps: f64,
                      disk_dir: Option<&std::path::Path>,
                      codec: KvCodecKind, hot_blocks: usize)
                      -> Result<Value> {
    use crate::config::{DiskWriteback, ServingConfig};
    use crate::coordinator::{Engine, Router, ServeEvent, ServeRequest};
    use crate::kvcache::{codec_for, DiskDocCache, HostDocCache};
    use crate::metrics::Metrics;
    use crate::rng::Rng;
    use crate::workload::synthetic_sample;
    use std::sync::Arc;

    let n_engines = n_engines.max(1);
    let metrics = Arc::new(Metrics::new());
    // one codec instance shared by the host pool and the disk tier,
    // mirroring the serve command's wiring, so the compression stats
    // aggregate in one place
    let codec_arc = codec_for(codec);
    let host = Arc::new(match disk_dir {
        Some(dir) => {
            let disk = Arc::new(DiskDocCache::open(dir, usize::MAX)?
                .with_codec(Arc::clone(&codec_arc)));
            HostDocCache::unbounded()
                .with_codec(Arc::clone(&codec_arc), hot_blocks)
                .with_disk(disk, DiskWriteback::Through)
        }
        None => HostDocCache::unbounded()
            .with_codec(Arc::clone(&codec_arc), hot_blocks),
    });
    let router = Arc::new(Router::new(n_engines));
    let defaults = ServingConfig::default();
    let cfg = ServingConfig {
        profile: profile.to_string(),
        max_batch: max_batch.max(1),
        // the pool must fit a full admission wave, or the engine would
        // silently clamp the sweep's batch axis to the default cap
        max_active: defaults.max_active.max(max_batch),
        kv_codec: codec,
        kv_hot_blocks: hot_blocks,
        ..defaults
    };
    let engines: Vec<Engine> = (0..n_engines)
        .map(|i| {
            Engine::spawn(i, artifacts_dir(), cfg.clone(),
                          policy.to_string(), Arc::clone(&metrics),
                          Arc::clone(&host),
                          Some(router.residency_handle(i)))
        })
        .collect::<Result<_>>()?;
    let handles: Vec<_> = engines.iter().map(|e| e.handle()).collect();

    // unique doc-sets generated once, then requests cycle over them
    let model = load_model(profile)?;
    let mut rng = Rng::new(2026);
    let pool: Vec<_> = (0..n_unique)
        .map(|_| synthetic_sample(&model.cfg, &mut rng))
        .collect();

    // paced open-loop arrivals: the engines' mid-round admission (not a
    // client-side in-flight window) is what bounds concurrency, so
    // queue-wait under pressure is actually measurable
    let gap = if arrival_rps > 0.0 {
        std::time::Duration::from_secs_f64(1.0 / arrival_rps)
    } else {
        std::time::Duration::ZERO
    };
    let t0 = std::time::Instant::now();
    // a collector thread drains completions (and calls `router.done`)
    // *while* submission continues — and in completion order, not
    // submission order, so one slow request can't head-of-line block
    // the load decrements — keeping the router's least-loaded placement
    // on live in-flight counts instead of totals that only drain after
    // the last submission
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let collector = {
        use std::sync::mpsc::TryRecvError;
        let router = Arc::clone(&router);
        std::thread::spawn(move || {
            let mut errors = 0usize;
            // every (request id, answer tokens) pair, for the
            // run-level answers_fnv digest
            let mut answers: Vec<(u64, Vec<i32>)> = Vec::new();
            let mut inflight: Vec<(usize, _)> = Vec::new();
            let mut open = true;
            loop {
                while open {
                    match done_rx.try_recv() {
                        Ok(x) => inflight.push(x),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => open = false,
                    }
                }
                let mut progressed = false;
                let mut i = 0;
                while i < inflight.len() {
                    // non-streaming requests: the only event is Done
                    let finished = match inflight[i].1.try_recv() {
                        Ok(ServeEvent::Done(r)) => {
                            if r.error.is_some() {
                                errors += 1;
                            }
                            answers.push((r.id, r.answer));
                            true
                        }
                        Ok(ServeEvent::Token { .. }) => false,
                        Err(TryRecvError::Empty) => false,
                        Err(TryRecvError::Disconnected) => {
                            errors += 1;
                            true
                        }
                    };
                    if finished {
                        let (engine, _) = inflight.swap_remove(i);
                        router.done(engine);
                        progressed = true;
                    } else {
                        i += 1;
                    }
                }
                if !open && inflight.is_empty() {
                    break (errors, answers);
                }
                if !progressed {
                    std::thread::sleep(
                        std::time::Duration::from_millis(1));
                }
            }
        })
    };
    for i in 0..n_requests {
        if i > 0 && !gap.is_zero() {
            std::thread::sleep(gap);
        }
        let sample = pool[i % n_unique].clone();
        let engine = router.pick(&sample);
        let rx = handles[engine].submit(ServeRequest {
            id: i as u64,
            sample,
            policy: policy.to_string(),
            stream: false,
        })?;
        let _ = done_tx.send((engine, rx));
    }
    drop(done_tx);
    let (errors, mut answers) = collector.join().expect("collector thread");
    // digest responses in request-id order (completion order is racy),
    // so two runs over the same workload compare token-for-token
    answers.sort_by_key(|(id, _)| *id);
    let answers_fnv = {
        let mut bytes = Vec::new();
        for (id, toks) in &answers {
            bytes.extend_from_slice(&id.to_le_bytes());
            for &t in toks {
                bytes.extend_from_slice(&t.to_le_bytes());
            }
        }
        crate::kvcache::store::fnv64(&bytes)
    };
    let wall_s = t0.elapsed().as_secs_f64();
    let rps = n_requests as f64 / wall_s;
    let load = |a: &std::sync::atomic::AtomicU64| {
        a.load(std::sync::atomic::Ordering::Relaxed) as i64
    };
    let tokens_per_s =
        metrics.tokens_generated.load(std::sync::atomic::Ordering::Relaxed)
            as f64
            / wall_s;
    println!("{}", metrics.report());
    println!("batch {max_batch}, rate {arrival_rps:.0} r/s: wall {:.1}s \
              -> {:.2} req/s, {:.1} tok/s, errors {}\n",
             wall_s, rps, tokens_per_s, errors);
    Ok(Value::obj()
        .set("model", profile)
        .set("policy", policy)
        .set("requests", n_requests)
        .set("unique_docsets", n_unique)
        .set("engines", n_engines)
        .set("max_batch", max_batch)
        .set("arrival_rps", arrival_rps)
        .set("wall_s", wall_s)
        .set("req_per_s", rps)
        .set("tokens_per_s", tokens_per_s)
        .set("errors", errors)
        .set("ttft_mean_ms", metrics.ttft.mean_ms())
        .set("ttft_p50_ms", metrics.ttft.percentile_ms(0.50))
        .set("ttft_p95_ms", metrics.ttft.percentile_ms(0.95))
        .set("e2e_p95_ms", metrics.e2e.percentile_ms(0.95))
        .set("plan_mean_ms", metrics.plan.mean_ms())
        .set("doc_prefill_mean_ms", metrics.doc_prefill.mean_ms())
        // continuous-batching scheduler measurements
        .set("queue_wait_mean_ms", metrics.queue_wait.mean_ms())
        .set("queue_wait_p50_ms", metrics.queue_wait.percentile_ms(0.50))
        .set("queue_wait_p95_ms", metrics.queue_wait.percentile_ms(0.95))
        .set("fused_rounds", load(&metrics.fused_rounds))
        .set("fused_round_sessions", load(&metrics.fused_round_sessions))
        // batched-decode dispatch accounting (one XLA execution per
        // same-buffer lane chunk) + admission/decode overlap
        .set("batched_rounds", load(&metrics.batched_rounds))
        .set("round_executions", load(&metrics.round_executions))
        .set("executions_per_round", metrics.executions_per_round())
        .set("lane_occupancy", metrics.lane_occupancy())
        .set("assemble_overlap_ms", metrics.assemble_overlap_ms())
        .set("doc_prefills", load(&metrics.doc_prefills))
        // per-tier document-cache counters (see Metrics)
        .set("host_hits", load(&metrics.host_hits))
        .set("host_misses", load(&metrics.host_misses))
        .set("host_publishes", load(&metrics.host_publishes))
        .set("host_evictions", load(&metrics.host_evictions))
        .set("host_bytes", load(&metrics.host_bytes))
        .set("resident_hits", load(&metrics.resident_hits))
        .set("resident_misses", load(&metrics.resident_misses))
        .set("resident_evictions", load(&metrics.resident_evictions))
        // persistent disk tier (zeros when no --disk-cache-dir)
        .set("disk_hits", load(&metrics.disk_hits))
        .set("disk_misses", load(&metrics.disk_misses))
        .set("disk_spills", load(&metrics.disk_spills))
        .set("disk_loads", load(&metrics.disk_loads))
        .set("disk_corrupt", load(&metrics.disk_corrupt))
        .set("disk_corrupt_blocks", load(&metrics.disk_corrupt_blocks))
        .set("disk_evictions", load(&metrics.disk_evictions))
        .set("disk_bytes", load(&metrics.disk_bytes))
        .set("disk_load_mean_ms", metrics.disk_load.mean_ms())
        // KV block-pool counters (slot gauges + monotone events; the
        // share-hit and partial-eviction counters are what the bench
        // smoke asserts to prove block-granular behaviour is live)
        .set("pool_slots_total", load(&metrics.pool_slots_total))
        .set("pool_slots_live", load(&metrics.pool_slots_live))
        .set("pool_slots_free", load(&metrics.pool_slots_free))
        .set("pool_slab_bytes", load(&metrics.pool_slab_bytes))
        .set("pool_grow_events", load(&metrics.pool_grow_events))
        .set("pool_blocks_evicted", load(&metrics.pool_blocks_evicted))
        .set("pool_blocks_spilled", load(&metrics.pool_blocks_spilled))
        .set("pool_share_hits", load(&metrics.pool_share_hits))
        .set("pool_partial_evictions",
             load(&metrics.pool_partial_evictions))
        // KV codec counters (the engine flushes the shared codec's
        // stats every admission wave; under f32 physical == logical
        // and the ratio is 1.0)
        .set("kv_codec", codec.name())
        .set("kv_hot_blocks", hot_blocks)
        .set("codec_blocks_encoded", load(&metrics.codec_blocks_encoded))
        .set("codec_blocks_decoded", load(&metrics.codec_blocks_decoded))
        .set("codec_logical_bytes", load(&metrics.codec_logical_bytes))
        .set("codec_physical_bytes", load(&metrics.codec_physical_bytes))
        .set("codec_compression_ratio", metrics.codec_compression_ratio())
        .set("codec_decode_mean_ms", metrics.codec_decode.mean_ms())
        .set("disk_bytes_loaded", load(&metrics.disk_bytes_loaded))
        // hex digest of all response tokens in request-id order: equal
        // digests mean token-identical output across runs
        .set("answers_fnv", format!("{answers_fnv:016x}")))
}

/// Cold-vs-warm-start pair over one persistent disk cache directory:
/// the first run prefills and spills every unique document
/// (write-through); the second rebuilds the whole process-side cache
/// stack over the same directory — a simulated server restart — and
/// must serve off disk with **zero** model prefills. Both runs use
/// `codec` for the cold host blocks and disk records, so the pair
/// also measures how much warm-restart I/O (`*_disk_bytes_loaded`)
/// the encoding saves, and `warm_matches_cold` reports whether the
/// restarted server produced token-identical answers (always true
/// under `f32`; lossy codecs may legitimately differ). The returned
/// row feeds the `restart`/`restart_codecs` objects of the throughput
/// sweep JSON and the distilled `BENCH_serving.json` artifact.
pub fn cold_warm_restart(profile: &str, policy: &str, n_requests: usize,
                         n_unique: usize, codec: KvCodecKind)
                         -> Result<Value> {
    let dir = std::env::temp_dir().join(format!(
        "samkv-bench-restart-{}-{}", std::process::id(), codec.name()));
    let _ = std::fs::remove_dir_all(&dir);
    println!("== Cold vs warm start (disk tier at {}, codec {}):",
             dir.display(), codec.name());
    let defaults = crate::config::ServingConfig::default();
    let cold = throughput_run(profile, policy, n_requests, n_unique, 1, 4,
                              0.0, Some(dir.as_path()), codec,
                              defaults.kv_hot_blocks)?;
    let warm = throughput_run(profile, policy, n_requests, n_unique, 1, 4,
                              0.0, Some(dir.as_path()), codec,
                              defaults.kv_hot_blocks)?;
    let _ = std::fs::remove_dir_all(&dir);
    let f = |v: &Value, k: &str| {
        v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0)
    };
    let s = |v: &Value, k: &str| {
        v.get(k).and_then(|x| x.as_str()).unwrap_or("").to_string()
    };
    let (cold_tps, warm_tps) =
        (f(&cold, "tokens_per_s"), f(&warm, "tokens_per_s"));
    let (cold_fnv, warm_fnv) =
        (s(&cold, "answers_fnv"), s(&warm, "answers_fnv"));
    let matches = !cold_fnv.is_empty() && cold_fnv == warm_fnv;
    println!("cold {:.1} tok/s ({} doc prefills) -> warm restart {:.1} \
              tok/s ({} doc prefills, {} disk hits, {:.1} KiB loaded, \
              answers {})\n",
             cold_tps, f(&cold, "doc_prefills") as u64, warm_tps,
             f(&warm, "doc_prefills") as u64,
             f(&warm, "disk_hits") as u64,
             f(&warm, "disk_bytes_loaded") / 1024.0,
             if matches { "identical" } else { "differ" });
    Ok(Value::obj()
        .set("kv_codec", codec.name())
        .set("cold_tokens_per_s", cold_tps)
        .set("warm_tokens_per_s", warm_tps)
        .set("warm_over_cold_pct", ratio_pct(warm_tps, cold_tps))
        .set("cold_doc_prefills", f(&cold, "doc_prefills"))
        .set("warm_doc_prefills", f(&warm, "doc_prefills"))
        .set("warm_disk_hits", f(&warm, "disk_hits"))
        .set("warm_ttft_p50_ms", f(&warm, "ttft_p50_ms"))
        .set("cold_ttft_p50_ms", f(&cold, "ttft_p50_ms"))
        // warm-restart I/O: file bytes read back off the disk tier —
        // the axis a compact encoding is supposed to shrink
        .set("cold_disk_bytes_loaded", f(&cold, "disk_bytes_loaded"))
        .set("warm_disk_bytes_loaded", f(&warm, "disk_bytes_loaded"))
        .set("codec_compression_ratio",
             f(&warm, "codec_compression_ratio"))
        .set("cold_answers_fnv", cold_fnv)
        .set("warm_answers_fnv", warm_fnv)
        .set("warm_matches_cold", matches))
}

/// Serving-throughput sweep over admission-wave size (`max_batch`) ×
/// open-loop arrival rate, persisting every run's row (tokens/sec,
/// TTFT p50/p95, queue-wait p50/p95, fused-round counters, per-tier
/// cache stats incl. the disk tier, codec counters) plus
/// cold-vs-warm-restart pairs under
/// `throughput_{profile}_{policy}.json`. Sweep rows run under
/// `codec`/`hot_blocks`; the restart experiment always runs once per
/// codec kind (`restart_codecs` array — the codec axis), with the
/// lossless `f32` pair duplicated as the legacy `restart` object so
/// its byte-identical warm path stays directly assertable.
pub fn throughput(profile: &str, policy: &str, n_requests: usize,
                  n_unique: usize, n_engines: usize,
                  batch_sizes: &[usize], rates: &[f64],
                  codec: KvCodecKind, hot_blocks: usize) -> Result<Value> {
    let batch_sizes: Vec<usize> = if batch_sizes.is_empty() {
        vec![4]
    } else {
        batch_sizes.to_vec()
    };
    let rates: Vec<f64> =
        if rates.is_empty() { vec![0.0] } else { rates.to_vec() };
    println!("== Serving throughput sweep: profile {profile}, policy \
              {policy}, {n_requests} requests over {n_unique} doc-sets, \
              {} engine(s), batch x rate = {:?} x {:?}\n",
             n_engines.max(1), batch_sizes, rates);
    let mut tbl = Table::new(&["batch", "rate r/s", "tok/s", "req/s",
                               "TTFT p50/p95 ms", "qwait p50/p95 ms"]);
    let mut rows = Vec::new();
    for &mb in &batch_sizes {
        for &rate in &rates {
            let row = throughput_run(profile, policy, n_requests, n_unique,
                                     n_engines, mb, rate, None, codec,
                                     hot_blocks)?;
            let f = |k: &str| {
                row.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
            };
            tbl.row(vec![
                format!("{mb}"),
                if rate > 0.0 { format!("{rate:.0}") }
                else { "max".to_string() },
                format!("{:.1}", f("tokens_per_s")),
                format!("{:.2}", f("req_per_s")),
                format!("{:.1}/{:.1}", f("ttft_p50_ms"), f("ttft_p95_ms")),
                format!("{:.1}/{:.1}", f("queue_wait_p50_ms"),
                        f("queue_wait_p95_ms")),
            ]);
            rows.push(row);
        }
    }
    tbl.print();
    // cold-vs-warm restart pairs over a persistent disk tier (kept
    // small: they exist to prove the zero-prefill warm path and give
    // the CI artifact restart rows, not to stress throughput). The
    // codec axis: one pair per encoding, f32 first so the legacy
    // `restart` object keeps its byte-identical lossless semantics.
    let mut restart = Value::Null;
    let mut restart_codecs = Vec::new();
    for k in [KvCodecKind::F32, KvCodecKind::F16, KvCodecKind::Int8] {
        let pair = cold_warm_restart(profile, policy, n_requests.min(8),
                                     n_unique.min(4), k)?;
        if k == KvCodecKind::F32 {
            restart = pair.clone();
        }
        restart_codecs.push(pair);
    }
    let v = Value::obj()
        .set("experiment", "throughput")
        .set("model", profile)
        .set("policy", policy)
        .set("requests", n_requests)
        .set("unique_docsets", n_unique)
        .set("engines", n_engines.max(1))
        .set("kv_codec", codec.name())
        .set("kv_hot_blocks", hot_blocks)
        .set("restart", restart)
        .set("restart_codecs", Value::Arr(restart_codecs))
        .set("rows", Value::Arr(rows));
    save_result(&format!("throughput_{profile}_{policy}"), &v)?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Chaos run — self-healing serving under a deterministic fault plan
// ---------------------------------------------------------------------------

/// One pass of the chaos workload through a real [`crate::server::Server`]
/// (TCP loopback, JSON-lines, so the self-healing retry/deadline path is
/// actually exercised): spawn `n_engines` engines over a shared host +
/// disk cache stack, drive `n_requests` from a small pool of worker
/// clients, and collect every terminal reply. Returns the metrics
/// registry, the per-request outcomes `(id, answer, error)` sorted by
/// id, the wall time, and how many requests never got a terminal reply
/// (hangs — the failure mode the chaos experiment exists to rule out).
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn chaos_pass(profile: &str, policy: &str,
              samples: &[crate::workload::Sample], n_requests: usize,
              n_engines: usize,
              plan: Option<std::sync::Arc<crate::faultinject::FaultPlan>>,
              timeout_ms: u64, disk_dir: &std::path::Path)
              -> Result<(std::sync::Arc<crate::metrics::Metrics>,
                         Vec<(usize, Option<Vec<i32>>, Option<String>)>,
                         f64, usize)> {
    use crate::config::{DiskWriteback, ServingConfig};
    use crate::coordinator::{Engine, Router};
    use crate::kvcache::{codec_for, DiskDocCache, HostDocCache};
    use crate::metrics::Metrics;
    use crate::server::{Client, Server};
    use std::sync::Arc;
    use std::time::Duration;

    let metrics = Arc::new(Metrics::new());
    let defaults = ServingConfig::default();
    // f32 codec: a retried request that lands on a healthy engine must
    // reproduce the baseline answer token-for-token
    let codec_arc = codec_for(KvCodecKind::F32);
    let mut disk = DiskDocCache::open(disk_dir, usize::MAX)?
        .with_codec(Arc::clone(&codec_arc))
        .with_breaker(defaults.disk_breaker_threshold,
                      Duration::from_millis(
                          defaults.disk_breaker_probe_ms));
    if let Some(p) = &plan {
        disk = disk.with_faults(Arc::clone(p));
    }
    let host = Arc::new(HostDocCache::unbounded()
        .with_codec(Arc::clone(&codec_arc), defaults.kv_hot_blocks)
        .with_disk(Arc::new(disk), DiskWriteback::Through));
    let router = Arc::new(Router::new(n_engines));
    let cfg = ServingConfig {
        profile: profile.to_string(),
        max_batch: 4,
        max_active: defaults.max_active.max(4),
        fault_plan: plan.clone(),
        request_timeout_ms: timeout_ms,
        ..defaults
    };
    let engines: Vec<Engine> = (0..n_engines)
        .map(|i| {
            Engine::spawn(i, artifacts_dir(), cfg.clone(),
                          policy.to_string(), Arc::clone(&metrics),
                          Arc::clone(&host),
                          Some(router.residency_handle(i)))
        })
        .collect::<Result<_>>()?;
    let handles: Vec<_> = engines.iter().map(|e| e.handle()).collect();
    let server = Server::with_router(handles, Arc::clone(&metrics),
                                     Arc::clone(&router))
        .with_resilience(cfg.request_retries, cfg.retry_backoff_ms,
                         timeout_ms)
        .with_faults(plan.clone());
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server.run("127.0.0.1:0", |p| {
            let _ = port_tx.send(p);
        })
    });
    let port = port_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("chaos server did not bind"))?;
    let addr = format!("127.0.0.1:{port}");

    // a small pool of synchronous client workers: each drives its slice
    // of the request ids over its own connection, so n_workers requests
    // are in flight at once and the router has real load to spread
    let t0 = std::time::Instant::now();
    let n_workers = n_engines.clamp(2, 4);
    let (res_tx, res_rx) = std::sync::mpsc::channel();
    let mut workers = Vec::new();
    for w in 0..n_workers {
        let addr = addr.clone();
        let res_tx = res_tx.clone();
        let policy = policy.to_string();
        let samples = samples.to_vec();
        workers.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).ok();
            let mut i = w;
            while i < n_requests {
                let s = &samples[i % samples.len()];
                let out = match client.as_mut() {
                    Some(c) => c.request(&s.docs, &s.query, &policy),
                    None => Err(anyhow::anyhow!("no connection")),
                };
                match out {
                    Ok(v) => {
                        let err = v
                            .get("error")
                            .and_then(|e| e.as_str())
                            .map(|e| e.to_string());
                        let ans = if err.is_none() {
                            v.get("answer").and_then(|a| a.i32_vec())
                        } else {
                            None
                        };
                        let _ = res_tx.send((i, ans, err));
                    }
                    Err(e) => {
                        // connection-level failure is a structured
                        // outcome too; reconnect for the next id
                        let _ = res_tx
                            .send((i, None, Some(format!("{e:#}"))));
                        client = Client::connect(&addr).ok();
                    }
                }
                i += n_workers;
            }
        }));
    }
    drop(res_tx);
    // collector-side watchdog: 60s per outstanding reply is orders of
    // magnitude beyond a tiny-profile decode — expiring means a client
    // is wedged in a blocking read with no terminal line coming
    let mut results: Vec<(usize, Option<Vec<i32>>, Option<String>)> =
        Vec::with_capacity(n_requests);
    let mut hangs = 0usize;
    for _ in 0..n_requests {
        match res_rx.recv_timeout(Duration::from_secs(60)) {
            Ok(r) => results.push(r),
            Err(_) => {
                hangs = n_requests - results.len();
                break;
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    if hangs == 0 {
        for w in workers {
            let _ = w.join();
        }
        if let Ok(mut stop) = Client::connect(&addr) {
            let _ = stop.shutdown();
        }
        let _ = server_thread.join();
        drop(engines);
    } else {
        // wedged threads: leave them detached — the caller is about to
        // fail the run, and joining a hung decode would hang the bench
        std::mem::forget(engines);
    }
    if let Some(p) = &plan {
        metrics.record_faults(p);
    }
    results.sort_by_key(|r| r.0);
    Ok((metrics, results, wall_s, hangs))
}

/// Chaos experiment: the throughput workload under a deterministic
/// fault plan (`--fault-plan` grammar, see [`crate::faultinject`]) —
/// typically killing one engine's decode thread mid-round and
/// injecting disk I/O faults under load — served through the
/// self-healing server path (engine supervision + bounded retries +
/// request deadlines + disk circuit breaker). Runs a no-fault baseline
/// pass first, then the chaos pass over the same request sequence, and
/// errors unless **every** request completed with a terminal reply
/// (answer or structured error — zero hangs). The persisted row
/// carries the completion/retry/timeout/engine-down accounting, the
/// per-site injection counters, the breaker counters, and
/// `answers_match_baseline` (under the lossless f32 codec, every
/// answered request must reproduce the baseline tokens).
pub fn chaos_run(profile: &str, policy: &str, n_requests: usize,
                 n_unique: usize, n_engines: usize, fault_spec: &str,
                 timeout_ms: u64) -> Result<Value> {
    use crate::faultinject::FaultPlan;
    use std::sync::Arc;

    let n_engines = n_engines.max(2); // self-healing needs a survivor
    let plan = Arc::new(FaultPlan::parse(fault_spec)?);
    println!("== Chaos run: profile {profile}, policy {policy}, \
              {n_requests} requests over {} doc-sets, {n_engines} \
              engines, plan `{}` (seed {})\n",
             n_unique.max(1), plan.spec(), plan.seed());
    let samples = {
        let model = load_model(profile)?;
        let mut rng = crate::rng::Rng::new(2026);
        (0..n_unique.max(1))
            .map(|_| crate::workload::synthetic_sample(&model.cfg,
                                                       &mut rng))
            .collect::<Vec<_>>()
        // the probe model (and its runtime) drops here, before the
        // engines spawn their own
    };
    let base_dir = std::env::temp_dir()
        .join(format!("samkv-chaos-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base_dir);

    let (_base_metrics, base_results, base_wall, base_hangs) =
        chaos_pass(profile, policy, &samples, n_requests, n_engines,
                   None, timeout_ms, &base_dir.join("baseline"))?;
    let (metrics, results, wall_s, hangs) =
        chaos_pass(profile, policy, &samples, n_requests, n_engines,
                   Some(Arc::clone(&plan)), timeout_ms,
                   &base_dir.join("chaos"))?;
    let _ = std::fs::remove_dir_all(&base_dir);

    let count_answered = |rs: &[(usize, Option<Vec<i32>>,
                                 Option<String>)]| {
        rs.iter().filter(|r| r.1.is_some()).count()
    };
    let answered = count_answered(&results);
    let structured_errors =
        results.iter().filter(|r| r.2.is_some()).count();
    let completed = results.len();
    let completed_pct = 100.0 * completed as f64 / n_requests as f64;
    let base_answers: std::collections::HashMap<usize, &Vec<i32>> =
        base_results
            .iter()
            .filter_map(|(i, a, _)| a.as_ref().map(|a| (*i, a)))
            .collect();
    let matched = results
        .iter()
        .filter(|(i, a, _)| match (a, base_answers.get(i)) {
            (Some(ans), Some(base)) => ans == *base,
            _ => false,
        })
        .count();

    let mut tbl = Table::new(&["pass", "wall s", "completed", "answered",
                               "errors", "hangs"]);
    tbl.row(vec![
        "baseline".to_string(),
        format!("{base_wall:.1}"),
        format!("{}", base_results.len()),
        format!("{}", count_answered(&base_results)),
        format!("{}", base_results.iter()
            .filter(|r| r.2.is_some()).count()),
        format!("{base_hangs}"),
    ]);
    tbl.row(vec![
        "chaos".to_string(),
        format!("{wall_s:.1}"),
        format!("{completed}"),
        format!("{answered}"),
        format!("{structured_errors}"),
        format!("{hangs}"),
    ]);
    tbl.print();
    println!("{}", metrics.report());
    println!("chaos: {completed}/{n_requests} completed \
              ({answered} answered, {structured_errors} structured \
              errors, {hangs} hangs), {matched}/{answered} answers \
              match baseline\n");

    let load = |a: &std::sync::atomic::AtomicU64| {
        a.load(std::sync::atomic::Ordering::Relaxed) as i64
    };
    let v = Value::obj()
        .set("experiment", "chaos")
        .set("model", profile)
        .set("policy", policy)
        .set("requests", n_requests)
        .set("unique_docsets", n_unique.max(1))
        .set("engines", n_engines)
        .set("fault_plan", plan.spec())
        .set("fault_seed", plan.seed() as i64)
        .set("request_timeout_ms", timeout_ms as i64)
        .set("wall_s", wall_s)
        .set("baseline_wall_s", base_wall)
        .set("completed", completed)
        .set("answered", answered)
        .set("structured_errors", structured_errors)
        .set("hangs", hangs)
        .set("baseline_hangs", base_hangs)
        .set("completed_pct", completed_pct)
        .set("answers_matching_baseline", matched)
        .set("answers_match_baseline",
             answered > 0 && matched == answered)
        .set("retries", load(&metrics.retries))
        .set("retry_successes", load(&metrics.retry_successes))
        .set("timeouts", load(&metrics.timeouts))
        .set("engine_down_events", load(&metrics.engine_down_events))
        .set("engines_down", load(&metrics.engines_down))
        .set("faults_injected", load(&metrics.faults_injected))
        .set("faults_disk_read", load(&metrics.faults_disk_read))
        .set("faults_disk_write", load(&metrics.faults_disk_write))
        .set("faults_disk_latency", load(&metrics.faults_disk_latency))
        .set("faults_corrupt_block",
             load(&metrics.faults_corrupt_block))
        .set("faults_codec_decode",
             load(&metrics.faults_codec_decode))
        .set("faults_doc_prefill", load(&metrics.faults_doc_prefill))
        .set("faults_engine_kill", load(&metrics.faults_engine_kill))
        .set("disk_io_errors", load(&metrics.disk_io_errors))
        .set("disk_breaker_opens", load(&metrics.disk_breaker_opens))
        .set("disk_breaker_closes", load(&metrics.disk_breaker_closes))
        .set("disk_breaker_short_circuits",
             load(&metrics.disk_breaker_short_circuits))
        .set("disk_quarantine_drops",
             load(&metrics.disk_quarantine_drops))
        .set("disk_quarantined_bytes",
             load(&metrics.disk_quarantined_bytes));
    save_result(&format!("chaos_{profile}_{policy}"), &v)?;
    anyhow::ensure!(
        base_hangs == 0 && hangs == 0,
        "chaos run hung: {hangs} chaos / {base_hangs} baseline \
         requests never got a terminal reply"
    );
    anyhow::ensure!(
        completed == n_requests,
        "chaos run incomplete: {completed}/{n_requests} terminal replies"
    );
    Ok(v)
}

// ---------------------------------------------------------------------------
// Peers run — cluster-wide exactly-once prefill over two in-process nodes
// ---------------------------------------------------------------------------

/// One in-process cluster node: a single-engine serving stack behind a
/// real TCP [`crate::server::Server`] with its host tier attached (so
/// the node answers `peer_get`), optionally configured with a
/// [`crate::server::peers::ClusterPeers`] fetcher.
struct PeerNode {
    metrics: std::sync::Arc<crate::metrics::Metrics>,
    addr: String,
    server: std::thread::JoinHandle<Result<()>>,
    engines: Vec<crate::coordinator::Engine>,
}

fn spawn_peer_node(
    profile: &str, policy: &str,
    cluster: Option<(usize, Vec<String>,
                     Option<std::sync::Arc<crate::faultinject::FaultPlan>>)>,
) -> Result<PeerNode> {
    use crate::config::ServingConfig;
    use crate::coordinator::{Engine, Router};
    use crate::kvcache::HostDocCache;
    use crate::metrics::Metrics;
    use crate::server::peers::ClusterPeers;
    use crate::server::Server;
    use std::sync::Arc;

    let metrics = Arc::new(Metrics::new());
    let defaults = ServingConfig::default();
    let mut host = HostDocCache::unbounded();
    if let Some((node_id, addrs, plan)) = cluster {
        let peers = ClusterPeers::new(node_id, addrs,
                                      defaults.peer_timeout_ms,
                                      Arc::clone(&metrics))
            .with_faults(plan);
        host = host.with_peers(Arc::new(peers));
    }
    let host = Arc::new(host);
    let router = Arc::new(Router::new(1));
    let cfg =
        ServingConfig { profile: profile.to_string(), ..defaults };
    let engines = vec![Engine::spawn(
        0, artifacts_dir(), cfg, policy.to_string(),
        Arc::clone(&metrics), Arc::clone(&host),
        Some(router.residency_handle(0)))?];
    let handles: Vec<_> = engines.iter().map(|e| e.handle()).collect();
    let server =
        Server::with_router(handles, Arc::clone(&metrics), router)
            .with_host(Arc::clone(&host));
    let (port_tx, port_rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server.run("127.0.0.1:0", |p| {
            let _ = port_tx.send(p);
        })
    });
    let port = port_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("peer node did not bind"))?;
    Ok(PeerNode {
        metrics,
        addr: format!("127.0.0.1:{port}"),
        server: server_thread,
        engines,
    })
}

fn shutdown_peer_node(node: PeerNode) {
    if let Ok(mut c) = crate::server::Client::connect(&node.addr) {
        let _ = c.shutdown();
    }
    let _ = node.server.join();
    drop(node.engines);
}

/// Drive `n_requests` through one node over a single client
/// connection at `arrival_rps` (0 = as fast as possible). Returns
/// `(completed, error_replies, answers_fnv, wall_s)` — the digest
/// covers every answered request's tokens in request order, so two
/// nodes serving the same workload compare token-for-token.
fn drive_peer_node(addr: &str, policy: &str,
                   samples: &[crate::workload::Sample],
                   n_requests: usize, arrival_rps: f64)
                   -> Result<(usize, usize, u64, f64)> {
    let mut client = crate::server::Client::connect(addr)?;
    let gap = if arrival_rps > 0.0 {
        std::time::Duration::from_secs_f64(1.0 / arrival_rps)
    } else {
        std::time::Duration::ZERO
    };
    let t0 = std::time::Instant::now();
    let (mut completed, mut errors) = (0usize, 0usize);
    let mut bytes = Vec::new();
    for i in 0..n_requests {
        if i > 0 && !gap.is_zero() {
            std::thread::sleep(gap);
        }
        let s = &samples[i % samples.len()];
        let v = client.request(&s.docs, &s.query, policy)?;
        completed += 1;
        if v.get("error").is_some() {
            errors += 1;
        } else if let Some(toks) =
            v.get("answer").and_then(|a| a.i32_vec())
        {
            bytes.extend_from_slice(&(i as u64).to_le_bytes());
            for t in toks {
                bytes.extend_from_slice(&t.to_le_bytes());
            }
        }
    }
    Ok((completed, errors, crate::kvcache::store::fnv64(&bytes),
        t0.elapsed().as_secs_f64()))
}

/// Two-node cluster smoke: proves the exactly-once prefill guarantee
/// is **cluster-wide**. Every document is steered (by mutating its
/// last token) to be rendezvous-owned by node 0; node 0 serves the
/// workload once (paying the only prefills in the cluster), then the
/// nodes × arrival-rate grid runs each cell on a **fresh** node — the
/// single-node cells re-prefill locally (the baseline), the two-node
/// cells must serve entirely over `peer_get` with **zero** model
/// prefills and token-identical answers. With a `--fault-plan`
/// carrying a `peer_fetch` site, a final pass proves injected peer
/// failures degrade to local prefills with 100% completion. The
/// persisted row also captures the typed `cmd:metrics` wire contract
/// (`schema_version` + the `peers` object).
pub fn peers_run(profile: &str, policy: &str, n_requests: usize,
                 n_unique: usize, fault_spec: Option<&str>)
                 -> Result<Value> {
    use crate::faultinject::FaultPlan;
    use crate::kvcache::doc_hash;
    use crate::server::peers::rendezvous_owner;
    use std::sync::Arc;

    let n_requests = n_requests.max(1);
    let plan = match fault_spec {
        Some(spec) => Some(Arc::new(FaultPlan::parse(spec)?)),
        None => None,
    };
    println!("== Peers run: profile {profile}, policy {policy}, \
              {n_requests} requests over {} doc-sets, 2 nodes{}\n",
             n_unique.max(1),
             match &plan {
                 Some(p) => format!(", plan `{}` (seed {})",
                                    p.spec(), p.seed()),
                 None => String::new(),
             });
    // steer every document's hash to node 0 of 2 so node 1's only
    // warm path is the peer fetch — doc_prefills==0 on node 1 then
    // IS the cluster-wide exactly-once assertion
    let samples = {
        let model = load_model(profile)?;
        let vocab = model.cfg.vocab as i32;
        let mut rng = crate::rng::Rng::new(2026);
        let mut ss: Vec<_> = (0..n_unique.max(1))
            .map(|_| crate::workload::synthetic_sample(&model.cfg,
                                                       &mut rng))
            .collect();
        for s in &mut ss {
            for doc in &mut s.docs {
                let last = doc.len() - 1;
                while rendezvous_owner(doc_hash(doc), 2) != 0 {
                    doc[last] = (doc[last] + 1).rem_euclid(vocab);
                }
            }
        }
        ss
        // the probe model (and its runtime) drops here, before the
        // nodes spawn their own
    };

    // node 0 — the owner. No peer fetcher of its own (it owns every
    // doc); its server answers `peer_get` from the attached host tier.
    let node_a = spawn_peer_node(profile, policy, None)?;
    let (a_completed, a_errors, a_fnv, _) =
        drive_peer_node(&node_a.addr, policy, &samples, n_requests,
                        0.0)?;
    anyhow::ensure!(a_completed == n_requests && a_errors == 0,
                    "owner node failed its warmup pass \
                     ({a_completed}/{n_requests}, {a_errors} errors)");
    let a_fnv = format!("{a_fnv:016x}");
    // node 1's peer list: [owner, self]. Its own slot is never dialed
    // (self-owned hashes skip the fetcher), so a placeholder is fine.
    let cluster_for = |plan: Option<Arc<FaultPlan>>| {
        (1usize, vec![node_a.addr.clone(), "127.0.0.1:1".to_string()],
         plan)
    };
    let load = |a: &std::sync::atomic::AtomicU64| {
        a.load(std::sync::atomic::Ordering::Relaxed) as i64
    };

    // the nodes × arrival-rate axis: every cell is a fresh (cold) node
    let rates = [0.0, 32.0];
    let mut tbl = Table::new(&["nodes", "rate r/s", "req/s",
                               "prefills", "peer hits", "peer miss"]);
    let mut rows = Vec::new();
    let mut exactly_once = true;
    let mut two_node_fnv = String::new();
    for nodes in [1usize, 2] {
        for rate in rates {
            let node = if nodes == 1 {
                spawn_peer_node(profile, policy, None)?
            } else {
                spawn_peer_node(profile, policy,
                                Some(cluster_for(None)))?
            };
            let (completed, errors, fnv, wall) =
                drive_peer_node(&node.addr, policy, &samples,
                                n_requests, rate)?;
            let m = Arc::clone(&node.metrics);
            let prefills = load(&m.doc_prefills);
            if nodes == 2 {
                exactly_once &= completed == n_requests
                    && errors == 0
                    && prefills == 0;
                if rate == 0.0 {
                    two_node_fnv = format!("{fnv:016x}");
                }
            }
            tbl.row(vec![
                format!("{nodes}"),
                if rate > 0.0 { format!("{rate:.0}") }
                else { "max".to_string() },
                format!("{:.2}", completed as f64 / wall.max(1e-9)),
                format!("{prefills}"),
                format!("{}", load(&m.peer_fetch_hits)),
                format!("{}", load(&m.peer_fetch_misses)),
            ]);
            rows.push(Value::obj()
                .set("nodes", nodes)
                .set("arrival_rps", rate)
                .set("requests", n_requests)
                .set("completed", completed)
                .set("errors", errors)
                .set("wall_s", wall)
                .set("req_per_s", completed as f64 / wall.max(1e-9))
                .set("doc_prefills", prefills)
                .set("peer_fetch_hits", load(&m.peer_fetch_hits))
                .set("peer_fetch_misses", load(&m.peer_fetch_misses))
                .set("peer_bytes_in", load(&m.peer_bytes_in))
                .set("peer_fetch_p50_ms",
                     m.peer_fetch.percentile_ms(0.50))
                .set("peer_fetch_p95_ms",
                     m.peer_fetch.percentile_ms(0.95))
                .set("answers_fnv", format!("{fnv:016x}")));
            shutdown_peer_node(node);
        }
    }
    tbl.print();

    // fault arm: injected peer-fetch failures must degrade to local
    // prefills — 100% completion, zero failed requests
    let fault_row = match &plan {
        Some(plan) => {
            let node = spawn_peer_node(
                profile, policy,
                Some(cluster_for(Some(Arc::clone(plan)))))?;
            let (completed, errors, fnv, _) =
                drive_peer_node(&node.addr, policy, &samples,
                                n_requests, 0.0)?;
            node.metrics.record_faults(plan);
            let row = Value::obj()
                .set("completed", completed)
                .set("errors", errors)
                .set("faults_peer_fetch",
                     load(&node.metrics.faults_peer_fetch))
                .set("peer_fetch_hits",
                     load(&node.metrics.peer_fetch_hits))
                .set("peer_fetch_misses",
                     load(&node.metrics.peer_fetch_misses))
                .set("doc_prefills", load(&node.metrics.doc_prefills))
                .set("answers_fnv", format!("{fnv:016x}"));
            println!("fault arm: {completed}/{n_requests} completed, \
                      {} injected peer faults, {} local prefills\n",
                     load(&node.metrics.faults_peer_fetch),
                     load(&node.metrics.doc_prefills));
            anyhow::ensure!(
                completed == n_requests && errors == 0,
                "peer fault plan broke completion \
                 ({completed}/{n_requests}, {errors} errors)");
            shutdown_peer_node(node);
            row
        }
        None => Value::Null,
    };

    // typed wire contract: schema stamp + the peers object, with the
    // owner's served bytes visible on it
    let wire = {
        let mut c = crate::server::Client::connect(&node_a.addr)?;
        c.metrics()?
    };
    let schema =
        wire.get("schema_version").and_then(|v| v.as_i64()).unwrap_or(0);
    anyhow::ensure!(
        schema as u32 == crate::server::protocol::METRICS_SCHEMA_VERSION,
        "metrics reply schema_version {schema} != {}",
        crate::server::protocol::METRICS_SCHEMA_VERSION);
    let bytes_out = wire
        .get("peers")
        .and_then(|p| p.get("bytes_out"))
        .and_then(|v| v.as_i64())
        .unwrap_or(-1);
    anyhow::ensure!(bytes_out > 0,
                    "owner served no peer bytes on the wire: {wire}");
    shutdown_peer_node(node_a);

    anyhow::ensure!(exactly_once,
                    "cluster-wide exactly-once violated: a two-node \
                     cell prefilled locally or dropped requests");
    anyhow::ensure!(two_node_fnv == a_fnv,
                    "two-node answers differ from the owner's \
                     ({two_node_fnv} != {a_fnv})");
    println!("peers: cluster-wide exactly-once holds (0 prefills on \
              node 1), answers identical across nodes\n");

    let v = Value::obj()
        .set("experiment", "peers")
        .set("model", profile)
        .set("policy", policy)
        .set("requests", n_requests)
        .set("unique_docsets", n_unique.max(1))
        .set("schema_version", schema)
        .set("exactly_once_cluster_wide", exactly_once)
        .set("owner_answers_fnv", a_fnv.as_str())
        .set("answers_match_owner", true)
        .set("fault_plan", fault_spec.unwrap_or(""))
        .set("fault_arm", fault_row)
        .set("rows", Value::Arr(rows));
    save_result(&format!("peers_{profile}_{policy}"), &v)?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_pct_guards_empty_baseline() {
        // regression: an empty recompute baseline row (mean TTFT 0)
        // must yield a finite ratio, not NaN/inf, so the persisted
        // experiment JSON stays parseable
        assert!((ratio_pct(50.0, 100.0) - 50.0).abs() < 1e-9);
        assert!(ratio_pct(0.0, 0.0).is_finite());
        assert_eq!(ratio_pct(0.0, 0.0), 0.0);
        assert!(ratio_pct(5.0, 0.0).is_finite());
    }

    #[test]
    fn parse_list_rejects_bad_entries() {
        assert_eq!(parse_list::<usize>("1, 4,8").unwrap(), vec![1, 4, 8]);
        assert_eq!(parse_list::<f64>("0,32.5").unwrap(), vec![0.0, 32.5]);
        assert!(parse_list::<usize>("1,x").is_err(),
                "bad entries must error, not shrink the sweep");
    }
}
