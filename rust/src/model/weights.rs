//! Weights file loader — format shared with `python/compile/train.py`:
//! `SAMKVW01` magic, little-endian u32 header length, JSON header
//! (`{"profile": ..., "arrays": [{"name", "shape"}, ...]}`), then the
//! concatenated little-endian f32 payloads.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::json;
use crate::tensor::Tensor;

pub const MAGIC: &[u8; 8] = b"SAMKVW01";

#[derive(Debug, Clone)]
pub struct NamedTensor {
    pub name: String,
    pub tensor: Tensor,
}

/// Parsed weights file.
#[derive(Debug, Clone)]
pub struct Weights {
    pub profile: String,
    pub arrays: Vec<NamedTensor>,
}

impl Weights {
    pub fn load(path: impl AsRef<Path>) -> Result<Weights> {
        let bytes = std::fs::read(path.as_ref()).with_context(|| {
            format!("reading weights {}", path.as_ref().display())
        })?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Weights> {
        if bytes.len() < 12 || &bytes[..8] != MAGIC {
            bail!("bad weights magic");
        }
        let hlen =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let header_end = 12 + hlen;
        if bytes.len() < header_end {
            bail!("truncated weights header");
        }
        let header = json::parse(
            std::str::from_utf8(&bytes[12..header_end])
                .context("weights header utf8")?,
        )?;
        let profile = header
            .req("profile")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("bad profile"))?
            .to_string();
        let mut arrays = Vec::new();
        let mut off = header_end;
        for spec in header
            .req("arrays")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("arrays not a list"))?
        {
            let name = spec
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("bad array name"))?
                .to_string();
            let shape = spec
                .req("shape")?
                .usize_vec()
                .ok_or_else(|| anyhow::anyhow!("bad array shape"))?;
            let n: usize = shape.iter().product();
            let end = off + 4 * n;
            if bytes.len() < end {
                bail!("truncated weights payload for `{name}`");
            }
            let data: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            arrays.push(NamedTensor { name, tensor: Tensor::new(shape, data)? });
            off = end;
        }
        if off != bytes.len() {
            bail!("trailing bytes in weights file ({} extra)",
                  bytes.len() - off);
        }
        Ok(Weights { profile, arrays })
    }

    pub fn total_params(&self) -> usize {
        self.arrays.iter().map(|a| a.tensor.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let header = r#"{"profile":"tiny","arrays":[
            {"name":"a","shape":[2,2]},{"name":"b","shape":[3]}]}"#;
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parses_sample() {
        let w = Weights::from_bytes(&sample_bytes()).unwrap();
        assert_eq!(w.profile, "tiny");
        assert_eq!(w.arrays.len(), 2);
        assert_eq!(w.arrays[0].name, "a");
        assert_eq!(w.arrays[0].tensor.shape(), &[2, 2]);
        assert_eq!(w.arrays[1].tensor.data(), &[5.0, 6.0, 7.0]);
        assert_eq!(w.total_params(), 7);
    }

    #[test]
    fn rejects_corruption() {
        let good = sample_bytes();
        assert!(Weights::from_bytes(&good[..good.len() - 1]).is_err());
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(Weights::from_bytes(&bad_magic).is_err());
        let mut trailing = good.clone();
        trailing.extend_from_slice(&[0u8; 4]);
        assert!(Weights::from_bytes(&trailing).is_err());
    }
}
