//! Typed wrappers over the AOT entry points of one model variant.
//!
//! A [`Model`] owns the device-ready weight literals and exposes the
//! serving calls with host-tensor signatures. All heavy compute happens
//! inside the artifacts; this layer only validates shapes and converts
//! buffers.
//!
//! # Lane-padded batched decode
//!
//! The decode hot path has two artifact shapes per buffer geometry: the
//! scalar entries (`decode_sparse` / `decode_full`, one sequence per
//! execution) and the lane-padded batched entries
//! (`decode_sparse_batched` / `decode_full_batched`), which take
//! `decode_lanes`-stacked token/pos/slot/KV/valid inputs plus a
//! per-lane `live` mask. [`Model::decode_batch`] packs a fused serving
//! round into lanes and issues **one** runtime execution per
//! (buffer-kind, lane-chunk) group — N same-buffer sessions with
//! `N <= decode_lanes` cost exactly one XLA execution — then scatters
//! the per-lane outputs back into per-request `Result`s. Per-lane fault
//! isolation is preserved: a request whose inputs fail validation (or
//! whose batched chunk fails at execute time, falling back to scalar
//! dispatch) never poisons its siblings. When the artifact set predates
//! the batched entries, every request takes the scalar path.

pub mod weights;

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::ProfileConfig;
use crate::runtime::{literal_to_tensor, Input, Runtime};
use crate::tensor::{ITensor, Tensor};
use weights::Weights;

/// Output of the per-document prefill.
#[derive(Debug, Clone)]
pub struct PrefillDocOut {
    /// `[L, 2, H, Ld, Dh]` — the document's KV cache (local positions).
    pub kv: Tensor,
    /// `[L, H, Ld, Ld]` — attention probabilities (Appendix-A input).
    pub attn: Tensor,
    /// `[L, H, Dh]` — mean post-RoPE Q over the local window (Eq. 1).
    pub q_local: Tensor,
}

/// Output of the user-query incremental prefill (§3.1).
#[derive(Debug, Clone)]
pub struct QueryEmbedOut {
    /// `[L, H, Dh]` — the generic query vector `Q_que`.
    pub q_que: Tensor,
    /// `[L, 2, H, Lq, Dh]` — the query tokens' own KV.
    pub q_kv: Tensor,
}

/// Output of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>,
    /// `[L, H, Dh]` — K/V of the decoded token (host mirrors the write).
    pub k_new: Tensor,
    pub v_new: Tensor,
}

/// One session's share of a fused decode round (see
/// [`Model::decode_batch`]): the same arguments as [`Model::decode`],
/// borrowing the session's assembled KV buffer.
#[derive(Debug)]
pub struct DecodeReq<'a> {
    pub buffer: Buffer,
    pub token: i32,
    pub pos: i32,
    pub slot: i32,
    pub kv: &'a Tensor,
    pub kv_valid: &'a [f32],
}

/// Outcome of one fused decode round ([`Model::decode_batch`]):
/// per-request results in request order plus the dispatch accounting
/// the scheduler metrics consume.
#[derive(Debug)]
pub struct DecodeRound {
    /// One `Result` per request, in request order — a failing request
    /// never poisons the rest of the round.
    pub results: Vec<Result<DecodeOut>>,
    /// Runtime executions issued for the round (scalar dispatches plus
    /// batched chunk launches, including failed launches whose lanes
    /// were retried on the scalar path).
    pub executions: u64,
    /// Live lanes dispatched through the batched entries.
    pub lanes_live: u64,
    /// Total lanes (live + padding) of those batched executions; zero
    /// when the round ran entirely on the scalar path.
    pub lanes_total: u64,
}

/// Which decode/recompute buffer geometry a call targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Buffer {
    /// Sparse assembled buffer (`sparse_len` slots) — SamKV/Multi-InfLLM.
    Sparse,
    /// Full joint buffer (`full_len` slots) — Recompute/CacheBlend/EPIC.
    Full,
}

pub struct Model {
    pub name: String,
    pub cfg: ProfileConfig,
    runtime: Rc<Runtime>,
    weight_lits: Vec<xla::Literal>,
    pub n_params: usize,
}

impl Model {
    /// Load a profile's weights and bind it to a runtime.
    pub fn load(runtime: Rc<Runtime>, profile: &str) -> Result<Model> {
        let meta = runtime.manifest().profile(profile)?.clone();
        let wpath = runtime.manifest().path(&meta.weights_file);
        let w = Weights::load(&wpath)?;
        if w.profile != profile {
            bail!("weights file is for `{}`, wanted `{profile}`", w.profile);
        }
        if w.arrays.len() != meta.n_weight_arrays {
            bail!("weights count {} != manifest {}", w.arrays.len(),
                  meta.n_weight_arrays);
        }
        let weight_lits = w
            .arrays
            .iter()
            .map(|a| crate::runtime::tensor_to_literal(&a.tensor))
            .collect::<Result<Vec<_>>>()?;
        let n_params = w.total_params();
        Ok(Model {
            name: profile.to_string(),
            cfg: meta.config,
            runtime,
            weight_lits,
            n_params,
        })
    }

    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.runtime
    }

    /// Pre-compile a chosen subset of entry points (the engine splits
    /// warmup between its decode thread and its admission helper —
    /// each thread lists exactly the entries it executes).
    /// Entries the artifact set does not provide are skipped, so
    /// optional computations (the batched decode variants) can be
    /// listed unconditionally.
    pub fn warmup_entries(&self, entries: &[&str]) -> Result<()> {
        let available: Vec<&str> = entries
            .iter()
            .copied()
            .filter(|e| self.has_entry(e))
            .collect();
        self.runtime.warmup(&self.name, &available)
    }

    /// Whether this model's artifact set provides an entry point.
    pub fn has_entry(&self, entry: &str) -> bool {
        self.runtime.has_entry(&self.name, entry)
    }

    /// Slot capacity of a buffer geometry.
    fn buffer_len(&self, buffer: Buffer) -> usize {
        match buffer {
            Buffer::Sparse => self.cfg.sparse_len,
            Buffer::Full => self.cfg.full_len,
        }
    }

    /// Lane count of the batched decode entry for `buffer`, or `None`
    /// when the artifact set predates the batched entries (or the
    /// profile was built with fewer than 2 lanes).
    pub fn batched_decode_lanes(&self, buffer: Buffer) -> Option<usize> {
        let entry = match buffer {
            Buffer::Sparse => "decode_sparse_batched",
            Buffer::Full => "decode_full_batched",
        };
        (self.cfg.decode_lanes >= 2 && self.has_entry(entry))
            .then_some(self.cfg.decode_lanes)
    }

    fn exec(&self, entry: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        self.runtime
            .execute(&self.name, entry, &self.weight_lits, inputs)?
            .iter()
            .map(literal_to_tensor)
            .collect()
    }

    /// Independent per-document prefill (positions `pos_offset..+Ld`).
    pub fn prefill_doc(&self, tokens: &[i32], pos_offset: i32)
                       -> Result<PrefillDocOut> {
        if tokens.len() != self.cfg.doc_len {
            bail!("prefill_doc wants {} tokens, got {}", self.cfg.doc_len,
                  tokens.len());
        }
        let mut outs = self.exec(
            "prefill_doc",
            &[ITensor::from_vec(tokens.to_vec()).into(),
              Input::from(pos_offset)],
        )?;
        let q_local = outs.pop().unwrap();
        let attn = outs.pop().unwrap();
        let kv = outs.pop().unwrap();
        Ok(PrefillDocOut { kv, attn, q_local })
    }

    /// Joint causal prefill over the padded full sequence.
    pub fn prefill_full(&self, tokens: &[i32], valid: &[f32])
                        -> Result<Tensor> {
        if tokens.len() != self.cfg.full_len {
            bail!("prefill_full wants {} tokens, got {}", self.cfg.full_len,
                  tokens.len());
        }
        let mut outs = self.exec(
            "prefill_full",
            &[ITensor::from_vec(tokens.to_vec()).into(),
              Tensor::new(vec![valid.len()], valid.to_vec())?.into()],
        )?;
        Ok(outs.pop().unwrap())
    }

    /// Incremental prefill of the user query over the compressed cache.
    pub fn query_embed(&self, q_tokens: &[i32], comp_kv: Tensor,
                       comp_valid: &[f32], q_pos: &[i32])
                       -> Result<QueryEmbedOut> {
        let mut outs = self.exec(
            "query_embed",
            &[ITensor::from_vec(q_tokens.to_vec()).into(),
              comp_kv.into(),
              Tensor::new(vec![comp_valid.len()], comp_valid.to_vec())?
                  .into(),
              ITensor::from_vec(q_pos.to_vec()).into()],
        )?;
        let q_kv = outs.pop().unwrap();
        let q_que = outs.pop().unwrap();
        Ok(QueryEmbedOut { q_que, q_kv })
    }

    /// Fig.-5 partial recomputation over a sparse/full buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn recompute(&self, buffer: Buffer, tokens: &[i32],
                     positions: &[i32], kv_in: &Tensor, rec_mask: Tensor,
                     valid: &[f32]) -> Result<Tensor> {
        let entry = match buffer {
            Buffer::Sparse => "recompute",
            Buffer::Full => "recompute_full",
        };
        let want = match buffer {
            Buffer::Sparse => self.cfg.sparse_len,
            Buffer::Full => self.cfg.full_len,
        };
        if tokens.len() != want {
            bail!("{entry} wants {want} slots, got {}", tokens.len());
        }
        // hot path: literals built directly, KV borrowed (no host clone)
        let lits = vec![
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(tokens.to_vec()))?,
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(positions.to_vec()))?,
            crate::runtime::tensor_to_literal(kv_in)?,
            crate::runtime::tensor_to_literal(&rec_mask)?,
            crate::runtime::tensor_to_literal(
                &Tensor::new(vec![valid.len()], valid.to_vec())?)?,
        ];
        let mut refs: Vec<&xla::Literal> =
            self.weight_lits.iter().collect();
        refs.extend(lits.iter());
        let mut outs = self
            .runtime
            .execute_literals(&self.name, entry, &refs)?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(outs.pop().unwrap())
    }

    /// One decode step over the assembled cache; the token's KV is placed
    /// in `slot` (the caller mirrors it into its host buffer).
    /// Delegates to [`Self::decode_batch`]; a single request always
    /// takes the scalar entry (exactly one runtime execution).
    pub fn decode(&self, buffer: Buffer, token: i32, pos: i32, slot: i32,
                  kv: &Tensor, kv_valid: &[f32]) -> Result<DecodeOut> {
        let req = DecodeReq { buffer, token, pos, slot, kv, kv_valid };
        self.decode_batch(std::slice::from_ref(&req))
            .results
            .pop()
            .expect("one decode result")
    }

    /// One scalar decode dispatch (also the per-lane fallback when a
    /// batched chunk fails at execute time).
    fn decode_one(&self, r: &DecodeReq) -> Result<DecodeOut> {
        let entry = match r.buffer {
            Buffer::Sparse => "decode_sparse",
            Buffer::Full => "decode_full",
        };
        // hot path: borrow the KV buffer; build literals directly
        let lits = [
            xla::Literal::scalar(r.token),
            xla::Literal::scalar(r.pos),
            xla::Literal::scalar(r.slot),
            crate::runtime::tensor_to_literal(r.kv)?,
            crate::runtime::tensor_to_literal(&Tensor::new(
                vec![r.kv_valid.len()],
                r.kv_valid.to_vec(),
            )?)?,
        ];
        let mut refs: Vec<&xla::Literal> =
            Vec::with_capacity(self.weight_lits.len() + lits.len());
        refs.extend(self.weight_lits.iter());
        refs.extend(lits.iter());
        let mut outs = self
            .runtime
            .execute_literals(&self.name, entry, &refs)?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap().into_data();
        Ok(DecodeOut { logits, k_new, v_new })
    }

    /// One batched chunk: stack up to `lanes` requests (`chunk` indexes
    /// into `reqs`) into the lane-padded entry and run it as a single
    /// execution. Outputs are returned in chunk order.
    fn decode_lanes(&self, buffer: Buffer, lanes: usize, chunk: &[usize],
                    reqs: &[DecodeReq]) -> Result<Vec<DecodeOut>> {
        let entry = match buffer {
            Buffer::Sparse => "decode_sparse_batched",
            Buffer::Full => "decode_full_batched",
        };
        let slots = self.buffer_len(buffer);
        let (nl, nh, dh) =
            (self.cfg.n_layers, self.cfg.n_heads, self.cfg.head_dim);
        let kv_stride = nl * 2 * nh * slots * dh;
        let mut tokens = vec![0i32; lanes];
        let mut positions = vec![0i32; lanes];
        let mut slot_ids = vec![0i32; lanes];
        // hot path: append live lanes then zero-resize the padding tail,
        // so live-lane KV bytes are written once (no zero prepass).
        // The stack-then-literal shape still costs one extra host copy
        // per live lane versus the scalar path's borrow-to-literal —
        // the literal API offers no per-lane writes — which the single
        // XLA launch amortizes across the lanes it replaces.
        let mut kv = Vec::with_capacity(lanes * kv_stride);
        let mut valid = Vec::with_capacity(lanes * slots);
        let mut live = vec![0f32; lanes];
        for (lane, &i) in chunk.iter().enumerate() {
            let r = &reqs[i];
            tokens[lane] = r.token;
            positions[lane] = r.pos;
            slot_ids[lane] = r.slot;
            kv.extend_from_slice(r.kv.data());
            valid.extend_from_slice(r.kv_valid);
            live[lane] = 1.0;
        }
        kv.resize(lanes * kv_stride, 0.0);
        valid.resize(lanes * slots, 0.0);
        let lits = [
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(tokens))?,
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(positions))?,
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(slot_ids))?,
            crate::runtime::tensor_to_literal(&Tensor::new(
                vec![lanes, nl, 2, nh, slots, dh], kv)?)?,
            crate::runtime::tensor_to_literal(&Tensor::new(
                vec![lanes, slots], valid)?)?,
            crate::runtime::tensor_to_literal(&Tensor::new(
                vec![lanes], live)?)?,
        ];
        let mut refs: Vec<&xla::Literal> =
            Vec::with_capacity(self.weight_lits.len() + lits.len());
        refs.extend(self.weight_lits.iter());
        refs.extend(lits.iter());
        let outs = self
            .runtime
            .execute_literals(&self.name, entry, &refs)?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        // outputs: logits [B, V], k_new [B, L, H, Dh], v_new [B, L, H, Dh]
        if outs.len() != 3 {
            // a malformed artifact must take the chunk's Err path (per
            // lane scalar fallback), not panic the decode thread
            bail!("{entry}: expected 3 outputs, got {}", outs.len());
        }
        let (logits_b, k_b, v_b) = (&outs[0], &outs[1], &outs[2]);
        (0..chunk.len())
            .map(|lane| {
                Ok(DecodeOut {
                    logits: logits_b.slice_at(&[lane]).to_vec(),
                    k_new: Tensor::new(vec![nl, nh, dh],
                                       k_b.slice_at(&[lane]).to_vec())?,
                    v_new: Tensor::new(vec![nl, nh, dh],
                                       v_b.slice_at(&[lane]).to_vec())?,
                })
            })
            .collect()
    }

    /// Fused decode round: one decode step for every request. Requests
    /// are grouped by buffer kind; each same-buffer group is packed
    /// into `decode_lanes`-wide chunks of the lane-padded batched entry
    /// — **one runtime execution per chunk**, so N same-buffer sessions
    /// with `N <= decode_lanes` cost a single XLA execution — and the
    /// per-lane outputs are scattered back into request order. A group
    /// (or trailing chunk) of one — or an artifact set without the
    /// batched entries — takes the scalar entry instead of paying for a
    /// mostly-padded lane launch. Per-lane fault isolation: a request with
    /// malformed inputs fails alone before stacking, and a batched
    /// chunk that fails at execute time is retried lane-by-lane on the
    /// scalar path so one poisoned lane cannot take down its siblings.
    pub fn decode_batch(&self, reqs: &[DecodeReq]) -> DecodeRound {
        let mut results: Vec<Option<Result<DecodeOut>>> =
            (0..reqs.len()).map(|_| None).collect();
        let mut executions = 0u64;
        let mut lanes_live = 0u64;
        let mut lanes_total = 0u64;
        for buffer in [Buffer::Sparse, Buffer::Full] {
            let idx: Vec<usize> = reqs
                .iter()
                .enumerate()
                .filter(|(_, r)| r.buffer == buffer)
                .map(|(i, _)| i)
                .collect();
            if idx.is_empty() {
                continue;
            }
            let slots = self.buffer_len(buffer);
            let kv_shape = [self.cfg.n_layers, 2, self.cfg.n_heads, slots,
                            self.cfg.head_dim];
            // per-lane input validation: a malformed request fails alone
            let mut live_idx: Vec<usize> = Vec::with_capacity(idx.len());
            for &i in &idx {
                let r = &reqs[i];
                if r.kv.shape() != &kv_shape[..] || r.kv_valid.len() != slots
                {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "decode lane {i}: kv shape {:?} / valid len {} do \
                         not match the {buffer:?} buffer ({slots} slots)",
                        r.kv.shape(), r.kv_valid.len())));
                } else {
                    live_idx.push(i);
                }
            }
            match self.batched_decode_lanes(buffer) {
                Some(lanes) if live_idx.len() >= 2 => {
                    for chunk in live_idx.chunks(lanes) {
                        executions += 1;
                        if chunk.len() == 1 {
                            // a trailing singleton chunk: the scalar
                            // entry beats a mostly-padded lane launch
                            results[chunk[0]] =
                                Some(self.decode_one(&reqs[chunk[0]]));
                            continue;
                        }
                        match self.decode_lanes(buffer, lanes, chunk, reqs)
                        {
                            Ok(outs) => {
                                // lane accounting only for launches
                                // that actually served their lanes, so
                                // occupancy/batched_rounds can't report
                                // healthy batching while every chunk
                                // falls back to scalar dispatch
                                lanes_live += chunk.len() as u64;
                                lanes_total += lanes as u64;
                                for (&i, out) in chunk.iter().zip(outs) {
                                    results[i] = Some(Ok(out));
                                }
                            }
                            Err(_) => {
                                // isolate the poisoned lane: retry each
                                // sibling alone on the scalar path
                                for &i in chunk {
                                    executions += 1;
                                    results[i] =
                                        Some(self.decode_one(&reqs[i]));
                                }
                            }
                        }
                    }
                }
                _ => {
                    for &i in &live_idx {
                        executions += 1;
                        results[i] = Some(self.decode_one(&reqs[i]));
                    }
                }
            }
        }
        DecodeRound {
            results: results
                .into_iter()
                .map(|r| r.expect("every request decided"))
                .collect(),
            executions,
            lanes_live,
            lanes_total,
        }
    }

    /// Offloaded block scoring (L1 Pallas kernel; weight-free artifact).
    pub fn score_blocks(&self, q_hat: Tensor, k_cache: Tensor,
                        valid: &[f32]) -> Result<Tensor> {
        let mut outs = self
            .runtime
            .execute(
                &self.name,
                "score_blocks",
                &[],
                &[q_hat.into(), k_cache.into(),
                  Tensor::new(vec![valid.len()], valid.to_vec())?.into()],
            )?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(outs.pop().unwrap())
    }

    /// Greedy argmax over logits. NaN-robust: NaN entries never win
    /// (and never poison later comparisons — the old
    /// `v > logits[best]` form silently returned token 0 whenever
    /// index 0 held a NaN), ties break to the lowest index, and an
    /// all-NaN/empty slice falls back to token 0.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            if v > best_v {
                best = i;
                best_v = v;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(Model::argmax(&[0.1, 3.0, -2.0, 3.0]), 1);
        assert_eq!(Model::argmax(&[-5.0]), 0);
    }

    #[test]
    fn argmax_ties_break_to_lowest_index() {
        assert_eq!(Model::argmax(&[1.0, 3.0, 3.0, 3.0]), 1);
        assert_eq!(Model::argmax(&[2.0, 2.0]), 0);
    }

    #[test]
    fn argmax_is_nan_robust() {
        // the seed bug: a NaN at index 0 made every `v > logits[best]`
        // comparison false and silently returned token 0
        assert_eq!(Model::argmax(&[f32::NAN, 1.0, 7.0, 2.0]), 2);
        // NaN elsewhere never wins either
        assert_eq!(Model::argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(Model::argmax(&[-1.0, f32::NEG_INFINITY, f32::NAN]), 0);
        // degenerate inputs fall back to token 0
        assert_eq!(Model::argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(Model::argmax(&[]), 0);
    }
}
