//! Typed wrappers over the AOT entry points of one model variant.
//!
//! A [`Model`] owns the device-ready weight literals and exposes the six
//! serving calls with host-tensor signatures. All heavy compute happens
//! inside the artifacts; this layer only validates shapes and converts
//! buffers.

pub mod weights;

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::ProfileConfig;
use crate::runtime::{literal_to_tensor, Input, Runtime};
use crate::tensor::{ITensor, Tensor};
use weights::Weights;

/// Output of the per-document prefill.
#[derive(Debug, Clone)]
pub struct PrefillDocOut {
    /// `[L, 2, H, Ld, Dh]` — the document's KV cache (local positions).
    pub kv: Tensor,
    /// `[L, H, Ld, Ld]` — attention probabilities (Appendix-A input).
    pub attn: Tensor,
    /// `[L, H, Dh]` — mean post-RoPE Q over the local window (Eq. 1).
    pub q_local: Tensor,
}

/// Output of the user-query incremental prefill (§3.1).
#[derive(Debug, Clone)]
pub struct QueryEmbedOut {
    /// `[L, H, Dh]` — the generic query vector `Q_que`.
    pub q_que: Tensor,
    /// `[L, 2, H, Lq, Dh]` — the query tokens' own KV.
    pub q_kv: Tensor,
}

/// Output of one decode step.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub logits: Vec<f32>,
    /// `[L, H, Dh]` — K/V of the decoded token (host mirrors the write).
    pub k_new: Tensor,
    pub v_new: Tensor,
}

/// One session's share of a fused decode round (see
/// [`Model::decode_batch`]): the same arguments as [`Model::decode`],
/// borrowing the session's assembled KV buffer.
#[derive(Debug)]
pub struct DecodeReq<'a> {
    pub buffer: Buffer,
    pub token: i32,
    pub pos: i32,
    pub slot: i32,
    pub kv: &'a Tensor,
    pub kv_valid: &'a [f32],
}

/// Which decode/recompute buffer geometry a call targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Buffer {
    /// Sparse assembled buffer (`sparse_len` slots) — SamKV/Multi-InfLLM.
    Sparse,
    /// Full joint buffer (`full_len` slots) — Recompute/CacheBlend/EPIC.
    Full,
}

pub struct Model {
    pub name: String,
    pub cfg: ProfileConfig,
    runtime: Rc<Runtime>,
    weight_lits: Vec<xla::Literal>,
    pub n_params: usize,
}

impl Model {
    /// Load a profile's weights and bind it to a runtime.
    pub fn load(runtime: Rc<Runtime>, profile: &str) -> Result<Model> {
        let meta = runtime.manifest().profile(profile)?.clone();
        let wpath = runtime.manifest().path(&meta.weights_file);
        let w = Weights::load(&wpath)?;
        if w.profile != profile {
            bail!("weights file is for `{}`, wanted `{profile}`", w.profile);
        }
        if w.arrays.len() != meta.n_weight_arrays {
            bail!("weights count {} != manifest {}", w.arrays.len(),
                  meta.n_weight_arrays);
        }
        let weight_lits = w
            .arrays
            .iter()
            .map(|a| crate::runtime::tensor_to_literal(&a.tensor))
            .collect::<Result<Vec<_>>>()?;
        let n_params = w.total_params();
        Ok(Model {
            name: profile.to_string(),
            cfg: meta.config,
            runtime,
            weight_lits,
            n_params,
        })
    }

    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.runtime
    }

    /// Pre-compile the entry points used on the serving path.
    pub fn warmup(&self) -> Result<()> {
        self.runtime.warmup(
            &self.name,
            &[
                "prefill_doc",
                "query_embed",
                "recompute",
                "decode_sparse",
                "score_blocks",
            ],
        )
    }

    fn exec(&self, entry: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        self.runtime
            .execute(&self.name, entry, &self.weight_lits, inputs)?
            .iter()
            .map(literal_to_tensor)
            .collect()
    }

    /// Independent per-document prefill (positions `pos_offset..+Ld`).
    pub fn prefill_doc(&self, tokens: &[i32], pos_offset: i32)
                       -> Result<PrefillDocOut> {
        if tokens.len() != self.cfg.doc_len {
            bail!("prefill_doc wants {} tokens, got {}", self.cfg.doc_len,
                  tokens.len());
        }
        let mut outs = self.exec(
            "prefill_doc",
            &[ITensor::from_vec(tokens.to_vec()).into(),
              Input::from(pos_offset)],
        )?;
        let q_local = outs.pop().unwrap();
        let attn = outs.pop().unwrap();
        let kv = outs.pop().unwrap();
        Ok(PrefillDocOut { kv, attn, q_local })
    }

    /// Joint causal prefill over the padded full sequence.
    pub fn prefill_full(&self, tokens: &[i32], valid: &[f32])
                        -> Result<Tensor> {
        if tokens.len() != self.cfg.full_len {
            bail!("prefill_full wants {} tokens, got {}", self.cfg.full_len,
                  tokens.len());
        }
        let mut outs = self.exec(
            "prefill_full",
            &[ITensor::from_vec(tokens.to_vec()).into(),
              Tensor::new(vec![valid.len()], valid.to_vec())?.into()],
        )?;
        Ok(outs.pop().unwrap())
    }

    /// Incremental prefill of the user query over the compressed cache.
    pub fn query_embed(&self, q_tokens: &[i32], comp_kv: Tensor,
                       comp_valid: &[f32], q_pos: &[i32])
                       -> Result<QueryEmbedOut> {
        let mut outs = self.exec(
            "query_embed",
            &[ITensor::from_vec(q_tokens.to_vec()).into(),
              comp_kv.into(),
              Tensor::new(vec![comp_valid.len()], comp_valid.to_vec())?
                  .into(),
              ITensor::from_vec(q_pos.to_vec()).into()],
        )?;
        let q_kv = outs.pop().unwrap();
        let q_que = outs.pop().unwrap();
        Ok(QueryEmbedOut { q_que, q_kv })
    }

    /// Fig.-5 partial recomputation over a sparse/full buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn recompute(&self, buffer: Buffer, tokens: &[i32],
                     positions: &[i32], kv_in: &Tensor, rec_mask: Tensor,
                     valid: &[f32]) -> Result<Tensor> {
        let entry = match buffer {
            Buffer::Sparse => "recompute",
            Buffer::Full => "recompute_full",
        };
        let want = match buffer {
            Buffer::Sparse => self.cfg.sparse_len,
            Buffer::Full => self.cfg.full_len,
        };
        if tokens.len() != want {
            bail!("{entry} wants {want} slots, got {}", tokens.len());
        }
        // hot path: literals built directly, KV borrowed (no host clone)
        let lits = vec![
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(tokens.to_vec()))?,
            crate::runtime::itensor_to_literal(
                &ITensor::from_vec(positions.to_vec()))?,
            crate::runtime::tensor_to_literal(kv_in)?,
            crate::runtime::tensor_to_literal(&rec_mask)?,
            crate::runtime::tensor_to_literal(
                &Tensor::new(vec![valid.len()], valid.to_vec())?)?,
        ];
        let mut refs: Vec<&xla::Literal> =
            self.weight_lits.iter().collect();
        refs.extend(lits.iter());
        let mut outs = self
            .runtime
            .execute_literals(&self.name, entry, &refs)?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(outs.pop().unwrap())
    }

    /// One decode step over the assembled cache; the token's KV is placed
    /// in `slot` (the caller mirrors it into its host buffer).
    pub fn decode(&self, buffer: Buffer, token: i32, pos: i32, slot: i32,
                  kv: &Tensor, kv_valid: &[f32]) -> Result<DecodeOut> {
        let req = DecodeReq { buffer, token, pos, slot, kv, kv_valid };
        self.decode_batch(std::slice::from_ref(&req))
            .pop()
            .expect("one decode result")
    }

    /// Fused decode round: one decode step for every request, dispatched
    /// in a single amortized loop — the weight argument prefix is
    /// assembled once per round instead of once per token (what
    /// per-call [`Model::decode`] used to pay), while each request's
    /// own literals (including its large KV-buffer copy) are built
    /// just-in-time so only one session's KV literal is alive at a
    /// time. Outcomes are returned in request order, one `Result` per
    /// request: a failing session never poisons the rest of the round.
    pub fn decode_batch(&self, reqs: &[DecodeReq])
                        -> Vec<Result<DecodeOut>> {
        let weight_refs: Vec<&xla::Literal> =
            self.weight_lits.iter().collect();
        reqs.iter()
            .map(|r| {
                let entry = match r.buffer {
                    Buffer::Sparse => "decode_sparse",
                    Buffer::Full => "decode_full",
                };
                // hot path: borrow the KV buffer; build literals directly
                let lits = [
                    xla::Literal::scalar(r.token),
                    xla::Literal::scalar(r.pos),
                    xla::Literal::scalar(r.slot),
                    crate::runtime::tensor_to_literal(r.kv)?,
                    crate::runtime::tensor_to_literal(&Tensor::new(
                        vec![r.kv_valid.len()],
                        r.kv_valid.to_vec(),
                    )?)?,
                ];
                let mut refs: Vec<&xla::Literal> =
                    Vec::with_capacity(weight_refs.len() + lits.len());
                refs.extend_from_slice(&weight_refs);
                refs.extend(lits.iter());
                let mut outs = self
                    .runtime
                    .execute_literals(&self.name, entry, &refs)?
                    .iter()
                    .map(literal_to_tensor)
                    .collect::<Result<Vec<_>>>()?;
                let v_new = outs.pop().unwrap();
                let k_new = outs.pop().unwrap();
                let logits = outs.pop().unwrap().into_data();
                Ok(DecodeOut { logits, k_new, v_new })
            })
            .collect()
    }

    /// Offloaded block scoring (L1 Pallas kernel; weight-free artifact).
    pub fn score_blocks(&self, q_hat: Tensor, k_cache: Tensor,
                        valid: &[f32]) -> Result<Tensor> {
        let mut outs = self
            .runtime
            .execute(
                &self.name,
                "score_blocks",
                &[],
                &[q_hat.into(), k_cache.into(),
                  Tensor::new(vec![valid.len()], valid.to_vec())?.into()],
            )?
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(outs.pop().unwrap())
    }

    /// Greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(Model::argmax(&[0.1, 3.0, -2.0, 3.0]), 1);
        assert_eq!(Model::argmax(&[-5.0]), 0);
    }
}
