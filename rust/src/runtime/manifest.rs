//! `artifacts/manifest.json` loader — the contract between the python
//! AOT build and the rust runtime (shapes, files, weight layout).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::ProfileConfig;
use crate::json::{self, Value};

/// Element dtype of an artifact argument/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    fn from_json(v: &Value) -> Result<ArgSpec> {
        let shape = v
            .req("shape")?
            .usize_vec()
            .ok_or_else(|| anyhow!("bad shape"))?;
        let dtype = match v.req("dtype")?.as_str() {
            Some("f32") => DType::F32,
            Some("i32") => DType::I32,
            other => anyhow::bail!("unsupported dtype {:?}", other),
        };
        Ok(ArgSpec { shape, dtype })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub file: String,
    pub needs_weights: bool,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// One model variant (tiny / s4 / m6).
#[derive(Debug, Clone)]
pub struct ProfileMeta {
    pub config: ProfileConfig,
    pub weights_file: String,
    pub n_weight_arrays: usize,
    pub entrypoints: BTreeMap<String, EntryMeta>,
    /// dataset name -> path relative to the artifacts dir
    pub datasets: BTreeMap<String, String>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub profiles: BTreeMap<String, ProfileMeta>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_str(&text, dir)
    }

    pub fn from_json_str(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let mut profiles = BTreeMap::new();
        for (name, pv) in root
            .req("profiles")?
            .members()
            .ok_or_else(|| anyhow!("profiles not an object"))?
        {
            let config = ProfileConfig::from_json(pv.req("config")?)
                .with_context(|| format!("profile {name} config"))?;
            let mut entrypoints = BTreeMap::new();
            for (ename, ev) in pv
                .req("entrypoints")?
                .members()
                .ok_or_else(|| anyhow!("entrypoints not an object"))?
            {
                let args = ev
                    .req("args")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("args not an array"))?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = ev
                    .req("outputs")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("outputs not an array"))?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                entrypoints.insert(
                    ename.clone(),
                    EntryMeta {
                        file: ev
                            .req("file")?
                            .as_str()
                            .ok_or_else(|| anyhow!("bad file"))?
                            .to_string(),
                        needs_weights: ev
                            .get("needs_weights")
                            .and_then(|v| v.as_bool())
                            .unwrap_or(true),
                        args,
                        outputs,
                    },
                );
            }
            let mut datasets = BTreeMap::new();
            if let Some(ds) = pv.get("datasets").and_then(|v| v.members()) {
                for (dname, dpath) in ds {
                    datasets.insert(
                        dname.clone(),
                        dpath
                            .as_str()
                            .ok_or_else(|| anyhow!("bad dataset path"))?
                            .to_string(),
                    );
                }
            }
            profiles.insert(
                name.clone(),
                ProfileMeta {
                    config,
                    weights_file: pv
                        .req("weights")?
                        .as_str()
                        .ok_or_else(|| anyhow!("bad weights"))?
                        .to_string(),
                    n_weight_arrays: pv
                        .req("n_weight_arrays")?
                        .as_usize()
                        .ok_or_else(|| anyhow!("bad n_weight_arrays"))?,
                    entrypoints,
                    datasets,
                },
            );
        }
        Ok(Manifest { dir, profiles })
    }

    pub fn profile(&self, name: &str) -> Result<&ProfileMeta> {
        self.profiles
            .get(name)
            .ok_or_else(|| anyhow!("unknown profile `{name}` in manifest"))
    }

    /// Absolute path of a profile-relative artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "profiles": {
        "tiny": {
          "config": {"name":"tiny","n_layers":2,"d_model":48,"n_heads":2,
            "head_dim":24,"d_ff":96,"vocab":256,"n_docs":2,"doc_len":32,
            "block_size":8,"init_blocks":1,"local_blocks":1,
            "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
            "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
            "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
            "blocks_per_doc":4},
          "weights": "tiny_weights.bin",
          "n_weight_arrays": 18,
          "entrypoints": {
            "prefill_doc": {
              "file": "tiny_prefill_doc.hlo.txt",
              "needs_weights": true,
              "args": [{"shape":[32],"dtype":"i32"},
                       {"shape":[],"dtype":"i32"}],
              "outputs": [{"shape":[2,2,2,32,24],"dtype":"f32"},
                          {"shape":[2,2,32,32],"dtype":"f32"},
                          {"shape":[2,2,24],"dtype":"f32"}]
            }
          },
          "datasets": {"hotpot-sim": "datasets/d2x32_hotpot-sim.json"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m =
            Manifest::from_json_str(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let p = m.profile("tiny").unwrap();
        assert_eq!(p.n_weight_arrays, 18);
        let e = &p.entrypoints["prefill_doc"];
        assert!(e.needs_weights);
        assert_eq!(e.args.len(), 2);
        assert_eq!(e.args[0].dtype, DType::I32);
        assert_eq!(e.outputs[0].shape, vec![2, 2, 2, 32, 24]);
        assert_eq!(e.outputs[0].numel(), 2 * 2 * 2 * 32 * 24);
        assert_eq!(p.datasets["hotpot-sim"], "datasets/d2x32_hotpot-sim.json");
        assert_eq!(m.path("x.hlo.txt"), PathBuf::from("/tmp/a/x.hlo.txt"));
        assert!(m.profile("nope").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration-style: parse the actual build output when available.
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.profiles.contains_key("tiny"));
            let p = m.profile("tiny").unwrap();
            assert_eq!(p.config.n_layers * 8 + 2, p.n_weight_arrays);
            for e in p.entrypoints.values() {
                assert!(dir.join(&e.file).exists(), "missing {}", e.file);
            }
        }
    }
}
