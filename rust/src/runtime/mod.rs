//! PJRT runtime: loads AOT HLO-text artifacts, compiles them once on the
//! CPU PJRT client, and executes them from the serving hot path.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a [`Runtime`] lives on one
//! thread; the coordinator owns it on a dedicated engine thread and other
//! threads talk to it through channels (see `coordinator::engine`).

mod literal;
pub mod manifest;

pub use literal::{
    itensor_to_literal, literal_scalar_f32, literal_to_itensor,
    literal_to_tensor, tensor_to_literal, Input,
};
pub use manifest::{ArgSpec, DType, EntryMeta, Manifest, ProfileMeta};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// Locate the artifacts directory: `$SAMKV_ARTIFACTS`, else `artifacts/`
/// under the crate root (works from `cargo test`/`bench`), else cwd.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SAMKV_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let candidate = manifest_dir.join("artifacts");
    if candidate.exists() {
        return candidate;
    }
    PathBuf::from("artifacts")
}

/// Per-entry-point execution accounting (feeds the §Perf analysis).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ms: f64,
    pub compile_ms: f64,
}

/// Artifact registry + executor. One per process/thread.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<(String, String), std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Runtime {
    pub fn new(artifacts: impl Into<PathBuf>) -> Result<Runtime> {
        let dir: PathBuf = artifacts.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Lazily load + compile an entry point.
    fn executable(
        &self,
        profile: &str,
        entry: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = (profile.to_string(), entry.to_string());
        if let Some(exe) = self.exes.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.profile(profile)?;
        let emeta = meta
            .entrypoints
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("unknown entrypoint `{entry}`"))?;
        let path = self.manifest.path(&emeta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {entry}: {e:?}"))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        crate::debug!("compiled {}:{} in {:.0}ms", profile, entry, compile_ms);
        self.stats
            .borrow_mut()
            .entry(format!("{profile}:{entry}"))
            .or_default()
            .compile_ms += compile_ms;
        let exe = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Whether the artifact set provides an entry point. Lets callers
    /// feature-gate on optional computations (e.g. the lane-padded
    /// `decode_{sparse,full}_batched` variants, absent from manifests
    /// built before they existed) instead of failing at execute time.
    pub fn has_entry(&self, profile: &str, entry: &str) -> bool {
        self.manifest
            .profile(profile)
            .map(|p| p.entrypoints.contains_key(entry))
            .unwrap_or(false)
    }

    /// Pre-compile a set of entry points (avoids first-request latency).
    pub fn warmup(&self, profile: &str, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.executable(profile, e)?;
        }
        Ok(())
    }

    /// Execute an entry point with pre-built literals (weights prepended
    /// by the caller when the entry needs them). Returns the flattened
    /// output tuple.
    pub fn execute_literals(
        &self,
        profile: &str,
        entry: &str,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(profile, entry)?;
        let t0 = Instant::now();
        let bufs = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {entry}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {entry}: {e:?}"))?;
        let outs = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {entry}: {e:?}"))?;
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(format!("{profile}:{entry}")).or_default();
        s.calls += 1;
        s.total_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(outs)
    }

    /// Execute with typed host inputs, validating shapes against the
    /// manifest. `weights` are prepended when the entry requires them.
    pub fn execute(
        &self,
        profile: &str,
        entry: &str,
        weights: &[xla::Literal],
        inputs: &[Input],
    ) -> Result<Vec<xla::Literal>> {
        let meta = self.manifest.profile(profile)?;
        let emeta = meta
            .entrypoints
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("unknown entrypoint `{entry}`"))?;
        if emeta.args.len() != inputs.len() {
            bail!(
                "{entry}: expected {} args, got {}",
                emeta.args.len(),
                inputs.len()
            );
        }
        for (i, (spec, input)) in emeta.args.iter().zip(inputs).enumerate() {
            if spec.shape != input.shape() {
                bail!(
                    "{entry} arg {i}: expected shape {:?}, got {:?}",
                    spec.shape,
                    input.shape()
                );
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()
            .context("building input literals")?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(
            weights.len() * (emeta.needs_weights as usize) + lits.len(),
        );
        if emeta.needs_weights {
            if weights.len() != meta.n_weight_arrays {
                bail!(
                    "{entry}: needs {} weight arrays, got {}",
                    meta.n_weight_arrays,
                    weights.len()
                );
            }
            refs.extend(weights.iter());
        }
        refs.extend(lits.iter());
        self.execute_literals(profile, entry, &refs)
    }

    /// Execute and convert all outputs to host f32 tensors.
    pub fn execute_f32(
        &self,
        profile: &str,
        entry: &str,
        weights: &[xla::Literal],
        inputs: &[Input],
    ) -> Result<Vec<Tensor>> {
        self.execute(profile, entry, weights, inputs)?
            .iter()
            .map(literal_to_tensor)
            .collect()
    }

    /// Snapshot of execution statistics.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<(String, ExecStats)> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}
