//! Host tensor <-> PJRT literal conversion.

use anyhow::{anyhow, bail, Result};

use crate::tensor::{ITensor, Tensor};

/// An input argument for an artifact execution.
#[derive(Debug, Clone)]
pub enum Input {
    F(Tensor),
    I(ITensor),
}

impl From<Tensor> for Input {
    fn from(t: Tensor) -> Self {
        Input::F(t)
    }
}
impl From<ITensor> for Input {
    fn from(t: ITensor) -> Self {
        Input::I(t)
    }
}
impl From<i32> for Input {
    fn from(v: i32) -> Self {
        Input::I(ITensor::scalar(v))
    }
}
impl From<f32> for Input {
    fn from(v: f32) -> Self {
        Input::F(Tensor::scalar(v))
    }
}

impl Input {
    pub fn shape(&self) -> &[usize] {
        match self {
            Input::F(t) => t.shape(),
            Input::I(t) => t.shape(),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Input::F(t) => tensor_to_literal(t),
            Input::I(t) => itensor_to_literal(t),
        }
    }
}

/// Raw byte view of a numeric slice (little-endian host).
fn as_bytes<T>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   std::mem::size_of_val(data))
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    if t.rank() == 0 {
        return Ok(xla::Literal::scalar(t.data()[0]));
    }
    // single-copy path (vec1+reshape would copy twice) — §Perf L3 opt 1
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        as_bytes(t.data()),
    )?)
}

pub fn itensor_to_literal(t: &ITensor) -> Result<xla::Literal> {
    if t.shape().is_empty() {
        return Ok(xla::Literal::scalar(t.data()[0]));
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        t.shape(),
        as_bytes(t.data()),
    )?)
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            Tensor::new(dims, lit.to_vec::<f32>()?)
        }
        other => bail!("expected f32 literal, got {:?}", other),
    }
}

pub fn literal_to_itensor(lit: &xla::Literal) -> Result<ITensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::S32 => {
            ITensor::new(dims, lit.to_vec::<i32>()?)
        }
        other => bail!("expected i32 literal, got {:?}", other),
    }
}

/// Scalar f32 extraction (logits reductions etc. are tensors; this is for
/// tiny outputs).
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar extract: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn itensor_roundtrip() {
        let t = ITensor::new(vec![4], vec![1, -2, 3, -4]).unwrap();
        let lit = itensor_to_literal(&t).unwrap();
        let back = literal_to_itensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        let lit = Input::from(42i32).to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
        let lit = Input::from(1.5f32).to_literal().unwrap();
        assert_eq!(literal_scalar_f32(&lit).unwrap(), 1.5);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = ITensor::from_vec(vec![1, 2]);
        let lit = itensor_to_literal(&t).unwrap();
        assert!(literal_to_tensor(&lit).is_err());
    }
}
