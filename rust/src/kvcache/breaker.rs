//! The disk tier's circuit-breaker state machine, factored out as a
//! pure core so it can be model-checked standalone.
//!
//! [`BreakerCore`] holds no lock and reads no clock: every method
//! takes `now_ms`, a caller-supplied monotonic millisecond timestamp.
//! [`super::DiskDocCache`] keeps one instance inside its single
//! `disk-index` lock (so no new lock-order edge exists) and derives
//! `now_ms` from a process epoch; `tests/loom_models.rs` wraps a core
//! in a facade mutex and drives synthetic timestamps through racing
//! probe threads — deterministic time is what makes the loom
//! exploration reproducible.
//!
//! State machine (`threshold` consecutive errors open; one probe
//! after `probe_ms`):
//!
//! ```text
//!            error × threshold                probe_ms elapsed
//!  Closed ───────────────────────▶ Open ─────────────────────▶ HalfOpen
//!    ▲                              ▲                             │
//!    │            ok (probe succeeded)│ error (probe failed)      │
//!    └─────────────────────────────┴──────────────────────────────┘
//! ```
//!
//! Invariants (asserted by the model):
//! * the breaker never closes except by a successful half-open probe;
//! * operations are short-circuited only while `Open` and before the
//!   probe interval elapses;
//! * open/close transition reports are exactly-once per transition,
//!   however many threads race their outcomes in.

/// What a [`BreakerCore::note_error`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerStep {
    /// No state transition.
    NoChange,
    /// This error opened the breaker. `failed_probe` distinguishes a
    /// half-open probe failure from a closed-state threshold trip.
    Opened { failed_probe: bool },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Normal service; consecutive I/O errors are being counted.
    Closed,
    /// Short-circuiting all disk I/O since `since_ms`.
    Open { since_ms: u64 },
    /// Probe window: operations run against the device again; the
    /// first outcome decides (success closes, error re-opens).
    HalfOpen,
}

/// Pure, clock-free circuit breaker. `threshold == 0` disables it
/// (never blocks, never transitions).
#[derive(Debug)]
pub struct BreakerCore {
    threshold: usize,
    probe_ms: u64,
    consec_errors: usize,
    state: State,
}

impl BreakerCore {
    pub fn new(threshold: usize, probe_ms: u64) -> BreakerCore {
        BreakerCore {
            threshold,
            probe_ms,
            consec_errors: 0,
            state: State::Closed,
        }
    }

    /// True while open or half-open (the "tripped" gauge).
    pub fn is_tripped(&self) -> bool {
        !matches!(self.state, State::Closed)
    }

    /// Consecutive errors counted since the last success (only
    /// meaningful while closed).
    pub fn consecutive_errors(&self) -> usize {
        self.consec_errors
    }

    /// Gate before an I/O operation: `true` means short-circuit it.
    /// An open breaker past its probe interval flips to half-open and
    /// admits this operation as the probe.
    pub fn blocks(&mut self, now_ms: u64) -> bool {
        if self.threshold == 0 {
            return false;
        }
        match self.state {
            State::Closed | State::HalfOpen => false,
            State::Open { since_ms } => {
                if now_ms.saturating_sub(since_ms) >= self.probe_ms {
                    self.state = State::HalfOpen;
                    false
                } else {
                    true
                }
            }
        }
    }

    /// Count one failed operation; reports an open transition
    /// exactly once per transition.
    pub fn note_error(&mut self, now_ms: u64) -> BreakerStep {
        if self.threshold == 0 {
            return BreakerStep::NoChange;
        }
        match self.state {
            State::HalfOpen => {
                // failed probe: straight back to open
                self.state = State::Open { since_ms: now_ms };
                BreakerStep::Opened { failed_probe: true }
            }
            State::Closed => {
                self.consec_errors += 1;
                if self.consec_errors >= self.threshold {
                    self.state = State::Open { since_ms: now_ms };
                    BreakerStep::Opened { failed_probe: false }
                } else {
                    BreakerStep::NoChange
                }
            }
            State::Open { .. } => BreakerStep::NoChange,
        }
    }

    /// Count one successful operation: resets the consecutive error
    /// run; returns `true` when a half-open probe success re-closed
    /// the breaker (exactly once per close).
    pub fn note_ok(&mut self) -> bool {
        self.consec_errors = 0;
        if matches!(self.state, State::HalfOpen) {
            self.state = State::Closed;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_zero_disables() {
        let mut b = BreakerCore::new(0, 10);
        assert_eq!(b.note_error(0), BreakerStep::NoChange);
        assert!(!b.blocks(1000));
        assert!(!b.is_tripped());
    }

    #[test]
    fn opens_after_threshold_probes_and_recloses() {
        let mut b = BreakerCore::new(2, 10);
        assert_eq!(b.note_error(0), BreakerStep::NoChange);
        assert_eq!(
            b.note_error(1),
            BreakerStep::Opened { failed_probe: false }
        );
        assert!(b.is_tripped());
        assert!(b.blocks(5), "open before the probe interval blocks");
        assert!(!b.blocks(11), "past the interval admits one probe");
        assert!(b.note_ok(), "probe success closes exactly once");
        assert!(!b.is_tripped());
        assert!(!b.note_ok(), "closed-state ok reports nothing");
    }

    #[test]
    fn failed_probe_reopens_with_fresh_interval() {
        let mut b = BreakerCore::new(1, 10);
        assert_eq!(
            b.note_error(0),
            BreakerStep::Opened { failed_probe: false }
        );
        assert!(!b.blocks(10));
        assert_eq!(
            b.note_error(10),
            BreakerStep::Opened { failed_probe: true }
        );
        assert!(b.blocks(15), "re-open restarts the probe dwell");
        assert!(!b.blocks(20));
    }

    #[test]
    fn ok_resets_consecutive_error_run() {
        let mut b = BreakerCore::new(3, 10);
        b.note_error(0);
        b.note_error(1);
        assert!(!b.note_ok());
        assert_eq!(b.consecutive_errors(), 0);
        b.note_error(2);
        b.note_error(3);
        assert_eq!(b.note_error(4),
                   BreakerStep::Opened { failed_probe: false });
    }

    #[test]
    fn open_state_errors_do_not_retransition() {
        let mut b = BreakerCore::new(1, 10);
        assert_eq!(b.note_error(0),
                   BreakerStep::Opened { failed_probe: false });
        assert_eq!(b.note_error(1), BreakerStep::NoChange);
        assert!(b.is_tripped());
    }
}
