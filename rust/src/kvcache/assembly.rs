//! Sparse-buffer assembly: packing selected (doc, block) KV into the
//! fixed-shape buffers the AOT artifacts consume.
//!
//! Slots carry three parallel annotations the policies need later:
//! token ids (for recomputation), *global* joint-layout positions (RoPE
//! for recomputed/decoded tokens + causal masking), and the originating
//! block (for write-back and ratio accounting).
//!
//! Document KV is **gathered straight out of the paged block pool**
//! ([`crate::kvcache::pool::KvBlocks::copy_span`]): an append reads
//! only the pool slots its token span touches, so assembling a sparse
//! buffer never materialises a document's full tensor. Blocks the
//! pool holds encoded (past the `--kv-hot-blocks` watermark under a
//! lossy `--kv-codec`) **dequantize during that gather**
//! ([`crate::kvcache::codec::KvCodec::decode_span`]) straight into
//! the f32 buffer being assembled — this module and everything
//! downstream (attention, decode) only ever see f32, and no
//! intermediate decoded copy of the block is materialised. Appending
//! a span whose pool block was evicted is an error — callers pin
//! their planned documents (or planned blocks) for exactly this
//! window.

use anyhow::{bail, Result};

use crate::config::ProfileConfig;
use crate::kvcache::store::DocEntry;
use crate::model::Buffer;
use crate::tensor::Tensor;

/// Why a block is in the buffer (paper §3.2 taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotKind {
    /// Initial-position block (kept at full resolution).
    Init,
    /// Local-position block (kept at full resolution).
    Local,
    /// Dynamically selected middle block (Eq. 2/3 + cross-filter).
    Selected,
    /// Whole-document block in a non-sparsified layout (Reuse/CacheBlend).
    Full,
}

/// One block's occupancy record.
#[derive(Debug, Clone)]
pub struct BlockRef {
    pub doc: usize,
    pub block: usize,
    pub kind: SlotKind,
    /// First buffer slot of this block.
    pub slot: usize,
}

/// A fixed-capacity KV buffer matching one artifact geometry.
#[derive(Debug, Clone)]
pub struct AssembledContext {
    pub buffer: Buffer,
    pub tokens: Vec<i32>,
    pub positions: Vec<i32>,
    pub valid: Vec<f32>,
    /// `[L, 2, H, S, Dh]`.
    pub kv: Tensor,
    /// Slots occupied by document KV (excludes query/decode tail).
    pub kv_len: usize,
    /// Next free slot.
    pub cursor: usize,
    pub blocks: Vec<BlockRef>,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    capacity: usize,
}

impl AssembledContext {
    pub fn new(cfg: &ProfileConfig, buffer: Buffer) -> AssembledContext {
        let capacity = match buffer {
            Buffer::Sparse => cfg.sparse_len,
            Buffer::Full => cfg.full_len,
        };
        AssembledContext {
            buffer,
            tokens: vec![0; capacity],
            positions: vec![0; capacity],
            valid: vec![0.0; capacity],
            kv: Tensor::zeros(&[cfg.n_layers, 2, cfg.n_heads, capacity,
                                cfg.head_dim]),
            kv_len: 0,
            cursor: 0,
            blocks: Vec::new(),
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            head_dim: cfg.head_dim,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Append one document block (KV copied verbatim — local-position
    /// RoPE, i.e. the paper's reused multiple-context cache).
    pub fn append_block(&mut self, cfg: &ProfileConfig, entry: &DocEntry,
                        doc: usize, block: usize, kind: SlotKind)
                        -> Result<()> {
        let bs = cfg.block_size;
        if self.cursor + bs > self.capacity {
            bail!("buffer overflow: {} + {} > {}", self.cursor, bs,
                  self.capacity);
        }
        let start_tok = block * bs;
        let slot = self.cursor;
        for t in 0..bs {
            self.tokens[slot + t] = entry.tokens[start_tok + t];
            self.positions[slot + t] =
                (cfg.doc_offset(doc) + start_tok + t) as i32;
            self.valid[slot + t] = 1.0;
        }
        for l in 0..self.n_layers {
            for c in 0..2 {
                for h in 0..self.n_heads {
                    let d = self.head_dim;
                    let dst = self.kv.slice_at_mut(&[l, c, h]);
                    entry.kv.copy_span(l, c, h, start_tok, bs,
                                       &mut dst[slot * d..(slot + bs) * d])?;
                }
            }
        }
        self.blocks.push(BlockRef { doc, block, kind, slot });
        self.cursor += bs;
        self.kv_len = self.cursor;
        Ok(())
    }

    /// Append every block of a document (Reuse / full-load baselines).
    pub fn append_doc(&mut self, cfg: &ProfileConfig, entry: &DocEntry,
                      doc: usize) -> Result<()> {
        for b in 0..cfg.blocks_per_doc {
            self.append_block(cfg, entry, doc, b, SlotKind::Full)?;
        }
        Ok(())
    }

    /// Reserve the next slot for a decoded/query token; returns the slot.
    /// The KV itself arrives via [`Self::write_token_kv`] after the
    /// decode artifact computes it.
    pub fn push_token(&mut self, token: i32, position: i32) -> Result<usize> {
        if self.cursor >= self.capacity {
            bail!("buffer overflow pushing token");
        }
        let slot = self.cursor;
        self.tokens[slot] = token;
        self.positions[slot] = position;
        self.cursor += 1;
        Ok(slot)
    }

    /// Mirror a decode step's K/V (`[L, H, Dh]` each) into `slot`.
    pub fn write_token_kv(&mut self, slot: usize, k_new: &Tensor,
                          v_new: &Tensor) {
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let d = self.head_dim;
                let k = &k_new.slice_at(&[l, h])[..d];
                let v = &v_new.slice_at(&[l, h])[..d];
                self.kv.slice_at_mut(&[l, 0, h])
                    [slot * d..(slot + 1) * d].copy_from_slice(k);
                self.kv.slice_at_mut(&[l, 1, h])
                    [slot * d..(slot + 1) * d].copy_from_slice(v);
            }
        }
        self.valid[slot] = 1.0;
    }

    /// Replace the whole KV tensor (post-recomputation write-back).
    pub fn replace_kv(&mut self, kv: Tensor) -> Result<()> {
        if kv.shape() != self.kv.shape() {
            bail!("kv shape mismatch: {:?} vs {:?}", kv.shape(),
                  self.kv.shape());
        }
        self.kv = kv;
        Ok(())
    }

    /// Fraction of the joint context length held in this buffer
    /// (the paper's *sequence ratio*, Table 1).
    pub fn seq_ratio(&self, cfg: &ProfileConfig) -> f64 {
        self.kv_len as f64 / cfg.ctx_len as f64
    }

    /// Bytes of KV loaded for inference (Fig.-1 circle size).
    pub fn kv_bytes(&self, cfg: &ProfileConfig) -> usize {
        self.kv_len * cfg.kv_bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::json;
    use crate::kvcache::pool::KvBlockPool;

    fn tiny_cfg() -> ProfileConfig {
        let v = json::parse(
            r#"{"name":"tiny","n_layers":2,"d_model":48,"n_heads":2,
                "head_dim":24,"d_ff":96,"vocab":256,"n_docs":2,"doc_len":32,
                "block_size":8,"init_blocks":1,"local_blocks":1,
                "sel_cap_blocks":2,"stable_layers":1,"rope_theta":10000.0,
                "query_len":5,"answer_max":4,"ctx_len":64,"full_len":73,
                "sparse_kv_len":48,"sparse_len":57,"comp_len":32,
                "blocks_per_doc":4}"#,
        )
        .unwrap();
        ProfileConfig::from_json(&v).unwrap()
    }

    fn fake_doc(cfg: &ProfileConfig, seed: i32) -> DocEntry {
        let ld = cfg.doc_len;
        let mut kv = Tensor::zeros(&[cfg.n_layers, 2, cfg.n_heads, ld,
                                     cfg.head_dim]);
        // tag each slot with a recognizable value: doc*1000 + token index
        for l in 0..cfg.n_layers {
            for c in 0..2 {
                for h in 0..cfg.n_heads {
                    let s = kv.slice_at_mut(&[l, c, h]);
                    for t in 0..ld {
                        for d in 0..cfg.head_dim {
                            s[t * cfg.head_dim + d] =
                                (seed * 1000 + t as i32) as f32;
                        }
                    }
                }
            }
        }
        let tokens: Vec<i32> = (0..ld as i32).map(|t| seed * 100 + t).collect();
        // 5-token pool blocks deliberately misalign with the 8-token
        // assembly block_size, so appends exercise cross-slot spans
        let pool = Arc::new(KvBlockPool::new(5));
        DocEntry::from_parts(
            &pool,
            tokens,
            kv,
            Tensor::zeros(&[cfg.n_layers, cfg.n_heads, ld, ld]),
            Tensor::zeros(&[cfg.n_layers, cfg.n_heads, cfg.head_dim]),
        )
        .unwrap()
    }

    #[test]
    fn append_block_copies_kv_and_annotations() {
        let cfg = tiny_cfg();
        let doc = fake_doc(&cfg, 2);
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        ctx.append_block(&cfg, &doc, 1, 3, SlotKind::Selected).unwrap();
        assert_eq!(ctx.kv_len, cfg.block_size);
        // token ids come from block 3 (tokens 24..32)
        assert_eq!(ctx.tokens[0], 2 * 100 + 24);
        // global positions: doc 1 offset 32, token 24 -> 56
        assert_eq!(ctx.positions[0], 56);
        assert_eq!(ctx.valid[7], 1.0);
        assert_eq!(ctx.valid[8], 0.0);
        // kv payload from the tagged source
        assert_eq!(ctx.kv.at(&[0, 0, 0, 0, 0]), 2024.0);
        assert_eq!(ctx.kv.at(&[1, 1, 1, 7, 3]), 2031.0);
        assert_eq!(ctx.blocks[0].slot, 0);
    }

    #[test]
    fn append_doc_fills_in_order() {
        let cfg = tiny_cfg();
        let doc = fake_doc(&cfg, 1);
        let mut ctx = AssembledContext::new(&cfg, Buffer::Full);
        ctx.append_doc(&cfg, &doc, 0).unwrap();
        assert_eq!(ctx.kv_len, cfg.doc_len);
        assert_eq!(ctx.blocks.len(), cfg.blocks_per_doc);
        assert_eq!(ctx.positions[31], 31);
        assert!((ctx.seq_ratio(&cfg) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn overflow_rejected() {
        let cfg = tiny_cfg();
        let doc = fake_doc(&cfg, 1);
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        // sparse capacity 57 -> 7 blocks fit, the 8th fails
        for b in 0..7 {
            ctx.append_block(&cfg, &doc, 0, b % 4, SlotKind::Full).unwrap();
        }
        assert!(ctx
            .append_block(&cfg, &doc, 0, 0, SlotKind::Full)
            .is_err());
    }

    #[test]
    fn append_from_evicted_pool_block_fails() {
        let cfg = tiny_cfg();
        let doc = fake_doc(&cfg, 1);
        // drop the first 5-token pool block: tokens 0..5 are holes
        doc.kv.take_block_data(0).unwrap();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        assert!(ctx
            .append_block(&cfg, &doc, 0, 0, SlotKind::Init)
            .is_err());
        // a span over still-resident pool blocks assembles fine
        ctx.append_block(&cfg, &doc, 0, 1, SlotKind::Local).unwrap();
        assert_eq!(ctx.kv.at(&[0, 0, 0, 0, 0]), 1008.0);
    }

    #[test]
    fn push_and_write_token_kv() {
        let cfg = tiny_cfg();
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        let slot = ctx.push_token(42, 64).unwrap();
        assert_eq!(slot, 0);
        assert_eq!(ctx.valid[0], 0.0); // not valid until kv written
        let k = Tensor::full(&[cfg.n_layers, cfg.n_heads, cfg.head_dim], 3.0);
        let v = Tensor::full(&[cfg.n_layers, cfg.n_heads, cfg.head_dim], 4.0);
        ctx.write_token_kv(slot, &k, &v);
        assert_eq!(ctx.valid[0], 1.0);
        assert_eq!(ctx.kv.at(&[1, 0, 1, 0, 5]), 3.0);
        assert_eq!(ctx.kv.at(&[0, 1, 0, 0, 0]), 4.0);
        // kv_len tracks doc blocks only, not decode tail
        assert_eq!(ctx.kv_len, 0);
    }

    #[test]
    fn seq_ratio_for_sparse_selection() {
        let cfg = tiny_cfg();
        let doc = fake_doc(&cfg, 1);
        let mut ctx = AssembledContext::new(&cfg, Buffer::Sparse);
        // 2 docs x (init + local) = 4 blocks of 8 = 32 slots over ctx 64
        for d in 0..2 {
            ctx.append_block(&cfg, &doc, d, 0, SlotKind::Init).unwrap();
            ctx.append_block(&cfg, &doc, d, 3, SlotKind::Local).unwrap();
        }
        assert!((ctx.seq_ratio(&cfg) - 0.5).abs() < 1e-9);
        assert_eq!(ctx.kv_bytes(&cfg), 32 * cfg.kv_bytes_per_token());
    }
}
