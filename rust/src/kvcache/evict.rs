//! Pluggable eviction for the document-cache tiers.
//!
//! All three tiers ([`super::HostDocCache`], [`super::EngineDocCache`],
//! and the persistent [`super::DiskDocCache`]) delegate victim
//! selection to an [`EvictionPolicy`]. The tier owns the mechanism —
//! byte accounting, pin filtering, the eviction loop, spilling a host
//! victim to disk before it leaves RAM — and hands the policy only
//! unpinned candidates; the policy owns the decision. Policies must be
//! `Send + Sync` because the host and disk tiers are shared across
//! engine threads.

/// One unpinned cache entry offered for eviction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictionCandidate {
    pub hash: u64,
    /// Bytes freed by evicting this entry.
    pub bytes: usize,
    /// Tier clock at the entry's last access (higher = more recent).
    pub last_use: u64,
    /// Proxy for the cost of re-creating the entry on a future miss:
    /// the document length in tokens (prefill cost scales with it).
    pub recompute_cost: usize,
}

/// Chooses which entry a tier evicts when over its byte budget.
pub trait EvictionPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Pick the victim's hash, or `None` to refuse (stops the eviction
    /// loop even if the tier is still over budget — e.g. every entry
    /// is pinned). Must return a hash from `candidates`.
    fn pick_victim(&self, candidates: &[EvictionCandidate]) -> Option<u64>;
}

/// Least-recently-used (the seed store's behaviour).
#[derive(Debug, Default, Clone, Copy)]
pub struct LruPolicy;

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn pick_victim(&self, candidates: &[EvictionCandidate]) -> Option<u64> {
        candidates.iter().min_by_key(|c| c.last_use).map(|c| c.hash)
    }
}

/// Cost-aware: evict the entry whose bytes are cheapest to get back —
/// the minimum recompute-cost per byte freed — so large, cheap entries
/// leave before small, expensive ones. Ties fall back to LRU.
#[derive(Debug, Default, Clone, Copy)]
pub struct CostAwarePolicy;

impl CostAwarePolicy {
    fn cost_per_byte(c: &EvictionCandidate) -> f64 {
        c.recompute_cost as f64 / c.bytes.max(1) as f64
    }
}

impl EvictionPolicy for CostAwarePolicy {
    fn name(&self) -> &'static str {
        "cost-aware"
    }

    fn pick_victim(&self, candidates: &[EvictionCandidate]) -> Option<u64> {
        candidates
            .iter()
            .min_by(|a, b| {
                Self::cost_per_byte(a)
                    .partial_cmp(&Self::cost_per_byte(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.last_use.cmp(&b.last_use))
            })
            .map(|c| c.hash)
    }
}

/// Look an eviction policy up by its CLI name.
pub fn eviction_policy_by_name(name: &str)
                               -> Option<Box<dyn EvictionPolicy>> {
    match name {
        "lru" => Some(Box::new(LruPolicy)),
        "cost-aware" => Some(Box::new(CostAwarePolicy)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(hash: u64, bytes: usize, last_use: u64, cost: usize)
            -> EvictionCandidate {
        EvictionCandidate { hash, bytes, last_use, recompute_cost: cost }
    }

    #[test]
    fn lru_picks_oldest() {
        let cs = [cand(1, 10, 5, 32), cand(2, 10, 3, 32),
                  cand(3, 10, 9, 32)];
        assert_eq!(LruPolicy.pick_victim(&cs), Some(2));
        assert_eq!(LruPolicy.pick_victim(&[]), None);
    }

    #[test]
    fn cost_aware_prefers_cheap_bytes() {
        // entry 1: huge but cheap to recompute; entry 2: small and
        // expensive per byte — 1 must go first despite being recent
        let cs = [cand(1, 4096, 9, 32), cand(2, 64, 1, 32)];
        assert_eq!(CostAwarePolicy.pick_victim(&cs), Some(1));
    }

    #[test]
    fn cost_aware_ties_fall_back_to_lru() {
        let cs = [cand(1, 100, 7, 50), cand(2, 100, 2, 50)];
        assert_eq!(CostAwarePolicy.pick_victim(&cs), Some(2));
        assert_eq!(CostAwarePolicy.pick_victim(&[]), None);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(eviction_policy_by_name("lru").unwrap().name(), "lru");
        assert_eq!(eviction_policy_by_name("cost-aware").unwrap().name(),
                   "cost-aware");
        assert!(eviction_policy_by_name("fifo").is_none());
    }
}
