//! Pluggable eviction for the document-cache tiers.
//!
//! All three tiers ([`super::HostDocCache`], [`super::EngineDocCache`],
//! and the persistent [`super::DiskDocCache`]) delegate victim
//! selection to an [`EvictionPolicy`]. The tier owns the mechanism —
//! byte accounting, pin filtering, the eviction loop, spilling a host
//! victim to disk before it leaves RAM — and hands the policy only
//! unpinned candidates; the policy owns the decision. Policies must be
//! `Send + Sync` because the host and disk tiers are shared across
//! engine threads.
//!
//! Since the paged block pool landed, candidates are **block-granular**
//! where the tier stores blocks: the host tier offers one candidate per
//! resident `(document, block)` pair, so a hot document's cold tail
//! blocks can leave independently while its pinned or recently-used
//! head stays warm. Tiers that still evict whole entries (the engine
//! residency map, per-file disk eviction) pass [`WHOLE_ENTRY`] as the
//! block index.

/// Block index marking a whole-entry candidate (tiers that don't
/// subdivide entries into pool blocks).
pub const WHOLE_ENTRY: u32 = u32::MAX;

/// One unpinned cache unit (a KV block, or a whole entry) offered for
/// eviction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictionCandidate {
    pub hash: u64,
    /// Block index within the document's pooled KV, or [`WHOLE_ENTRY`]
    /// for doc-granular tiers. Within one document all blocks share a
    /// `last_use` (the tiers track recency per entry), so policies use
    /// the block index as the intra-document tie-break: **higher blocks
    /// first** — the tail of a document is colder than its head under
    /// causal attention (prefix reuse keeps heads hot).
    pub block: u32,
    /// Bytes freed by evicting this unit.
    pub bytes: usize,
    /// Tier clock at the entry's last access (higher = more recent).
    pub last_use: u64,
    /// Proxy for the cost of re-creating the unit on a future miss:
    /// the document length in tokens (prefill cost scales with it —
    /// a single block still costs a whole-document prefill when the
    /// disk tier can't supply it).
    pub recompute_cost: usize,
}

/// Chooses which unit a tier evicts when over its byte budget.
pub trait EvictionPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Pick the victim's **index into `candidates`**, or `None` to
    /// refuse (stops the eviction loop even if the tier is still over
    /// budget — e.g. every candidate is pinned).
    fn pick_victim(&self, candidates: &[EvictionCandidate])
                   -> Option<usize>;
}

/// Least-recently-used (the seed store's behaviour), tail blocks first
/// within one document.
#[derive(Debug, Default, Clone, Copy)]
pub struct LruPolicy;

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn pick_victim(&self, candidates: &[EvictionCandidate])
                   -> Option<usize> {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, c)| (c.last_use, std::cmp::Reverse(c.block)))
            .map(|(i, _)| i)
    }
}

/// Cost-aware: evict the unit whose bytes are cheapest to get back —
/// the minimum recompute-cost per byte freed — so large, cheap blocks
/// leave before small, expensive ones. Ties fall back to LRU, then to
/// tail-blocks-first.
#[derive(Debug, Default, Clone, Copy)]
pub struct CostAwarePolicy;

impl CostAwarePolicy {
    fn cost_per_byte(c: &EvictionCandidate) -> f64 {
        c.recompute_cost as f64 / c.bytes.max(1) as f64
    }
}

impl EvictionPolicy for CostAwarePolicy {
    fn name(&self) -> &'static str {
        "cost-aware"
    }

    fn pick_victim(&self, candidates: &[EvictionCandidate])
                   -> Option<usize> {
        candidates
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                Self::cost_per_byte(a)
                    .partial_cmp(&Self::cost_per_byte(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.last_use.cmp(&b.last_use))
                    .then(b.block.cmp(&a.block))
            })
            .map(|(i, _)| i)
    }
}

/// Look an eviction policy up by its CLI name.
pub fn eviction_policy_by_name(name: &str)
                               -> Option<Box<dyn EvictionPolicy>> {
    match name {
        "lru" => Some(Box::new(LruPolicy)),
        "cost-aware" => Some(Box::new(CostAwarePolicy)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(hash: u64, bytes: usize, last_use: u64, cost: usize)
            -> EvictionCandidate {
        EvictionCandidate { hash, block: WHOLE_ENTRY, bytes, last_use,
                            recompute_cost: cost }
    }

    fn block_cand(hash: u64, block: u32, last_use: u64)
                  -> EvictionCandidate {
        EvictionCandidate { hash, block, bytes: 100, last_use,
                            recompute_cost: 32 }
    }

    #[test]
    fn lru_picks_oldest() {
        let cs = [cand(1, 10, 5, 32), cand(2, 10, 3, 32),
                  cand(3, 10, 9, 32)];
        assert_eq!(LruPolicy.pick_victim(&cs), Some(1));
        assert_eq!(LruPolicy.pick_victim(&[]), None);
    }

    #[test]
    fn lru_evicts_tail_blocks_of_a_document_first() {
        // same doc, same last_use: the coldest (highest) block goes
        // first, so a document drains tail-to-head
        let cs = [block_cand(7, 0, 4), block_cand(7, 2, 4),
                  block_cand(7, 1, 4), block_cand(9, 3, 9)];
        assert_eq!(LruPolicy.pick_victim(&cs), Some(1),
                   "block 2 is the cold tail of the LRU doc");
    }

    #[test]
    fn cost_aware_prefers_cheap_bytes() {
        // entry 1: huge but cheap to recompute; entry 2: small and
        // expensive per byte — 1 must go first despite being recent
        let cs = [cand(1, 4096, 9, 32), cand(2, 64, 1, 32)];
        assert_eq!(CostAwarePolicy.pick_victim(&cs), Some(0));
    }

    #[test]
    fn cost_aware_ties_fall_back_to_lru_then_tail_block() {
        let cs = [cand(1, 100, 7, 50), cand(2, 100, 2, 50)];
        assert_eq!(CostAwarePolicy.pick_victim(&cs), Some(1));
        assert_eq!(CostAwarePolicy.pick_victim(&[]), None);
        // full tie on cost and recency: tail block wins
        let cs = [block_cand(7, 1, 4), block_cand(7, 3, 4),
                  block_cand(7, 0, 4)];
        assert_eq!(CostAwarePolicy.pick_victim(&cs), Some(1));
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(eviction_policy_by_name("lru").unwrap().name(), "lru");
        assert_eq!(eviction_policy_by_name("cost-aware").unwrap().name(),
                   "cost-aware");
        assert!(eviction_policy_by_name("fifo").is_none());
    }
}
