//! Pluggable KV block codecs: how a block's f32 payload is byte-encoded
//! when it leaves the hot path — host-tier cold blocks past the
//! `--kv-hot-blocks` watermark and every disk-tier block record.
//!
//! A [`KvCodec`] maps one **logical block payload** (the channel-major
//! `block_len × per_token_elems` f32 slice the pool and disk tier
//! already exchange) to an opaque byte payload and back:
//!
//! * [`LosslessF32`] (`--kv-codec f32`, the default) — raw
//!   little-endian f32 bytes, byte-identical round trip. Every v2 disk
//!   record decodes through this codec.
//! * [`F16Codec`] (`f16`) — IEEE half precision, hand-rolled bit
//!   conversion (round-to-nearest-even), 2× smaller. Non-finite
//!   elements sanitize to 0.0 and magnitudes clamp to ±65504.
//! * [`Int8BlockCodec`] (`int8`) — per-block absmax quantization: one
//!   f32 scale (absmax/127, computed over the block's finite elements)
//!   followed by one i8 per element, ~4× smaller. Non-finite elements
//!   quantize to 0.
//!
//! Dequantization happens **on read** — [`super::pool::KvBlocks`]
//! decodes spans straight into the f32 assembly scratch
//! ([`KvCodec::decode_span`]), so attention/decode consumers never see
//! encoded bytes. On disk, the payload (scale included) rides *under*
//! the existing per-record FNV-1a checksum, so a flipped scale byte is
//! caught like any other corruption (format v3, see [`super::disk`]).
//!
//! Each codec instance carries its own [`CodecStats`] (blocks
//! encoded/decoded, logical vs physical bytes, buffered decode-time
//! samples). The serving stack builds **one instance per process**
//! ([`codec_for`]) and shares the `Arc` between the host pool and the
//! disk tier, so the stats aggregate across tiers; [`codec_by_id`]
//! supplies process-wide fallback instances for decoding records
//! written under a different codec than the session's (a warm restart
//! over old files).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::KvCodecKind;
use crate::sync::Mutex;

/// Wire ids (disk v3 per-record codec tag). Stable forever: files
/// outlive binaries.
pub const CODEC_F32: u8 = 0;
pub const CODEC_F16: u8 = 1;
pub const CODEC_INT8: u8 = 2;

/// Decode-latency samples buffered until the next
/// [`CodecStats::take_decode_samples`] drain (mirrors the disk tier's
/// load-sample buffer).
const MAX_DECODE_SAMPLES: usize = 4096;

/// Per-codec-instance counters. All monotone lifetime totals; the
/// decode-time samples are a drain-on-read buffer for the metrics
/// histogram.
#[derive(Debug)]
pub struct CodecStats {
    blocks_encoded: AtomicU64,
    blocks_decoded: AtomicU64,
    /// f32 bytes represented by every encode (4 × elements).
    logical_bytes: AtomicU64,
    /// Encoded bytes actually produced by every encode.
    physical_bytes: AtomicU64,
    decode_ms: Mutex<Vec<f64>>,
}

// Manual impl: the lock-class-named mutex has no `Default`.
impl Default for CodecStats {
    fn default() -> CodecStats {
        CodecStats {
            blocks_encoded: AtomicU64::new(0),
            blocks_decoded: AtomicU64::new(0),
            logical_bytes: AtomicU64::new(0),
            physical_bytes: AtomicU64::new(0),
            decode_ms: Mutex::named("codec-stats", Vec::new()),
        }
    }
}

impl CodecStats {
    fn note_encode(&self, n_elems: usize, physical: usize) {
        self.blocks_encoded.fetch_add(1, Ordering::Relaxed);
        self.logical_bytes
            .fetch_add(n_elems as u64 * 4, Ordering::Relaxed);
        self.physical_bytes
            .fetch_add(physical as u64, Ordering::Relaxed);
    }

    fn note_decode(&self, ms: f64) {
        self.blocks_decoded.fetch_add(1, Ordering::Relaxed);
        let mut g = self.decode_ms.lock();
        if g.len() < MAX_DECODE_SAMPLES {
            g.push(ms);
        }
    }

    /// Drain the decode-latency samples (milliseconds) buffered since
    /// the previous drain — the engine feeds them into the metrics
    /// histogram after every admission wave.
    pub fn take_decode_samples(&self) -> Vec<f64> {
        std::mem::take(&mut *self.decode_ms.lock())
    }

    pub fn snapshot(&self, codec: &'static str) -> CodecSnapshot {
        CodecSnapshot {
            codec,
            blocks_encoded: self.blocks_encoded.load(Ordering::Relaxed),
            blocks_decoded: self.blocks_decoded.load(Ordering::Relaxed),
            logical_bytes: self.logical_bytes.load(Ordering::Relaxed),
            physical_bytes: self.physical_bytes.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of one codec's counters (what flows into
/// [`crate::metrics::Metrics::record_codec`] and the bench rows).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CodecSnapshot {
    pub codec: &'static str,
    pub blocks_encoded: u64,
    pub blocks_decoded: u64,
    pub logical_bytes: u64,
    pub physical_bytes: u64,
}

impl CodecSnapshot {
    /// logical / physical bytes over everything encoded so far (1.0
    /// before any encode, so the lossless default reports a neutral
    /// ratio instead of 0/0).
    pub fn compression_ratio(&self) -> f64 {
        if self.physical_bytes == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / self.physical_bytes as f64
        }
    }
}

/// One block encoding. Implementations are stateless apart from their
/// [`CodecStats`]; `encode_block` / `decode_block` / `decode_span`
/// record into them.
pub trait KvCodec: Send + Sync + std::fmt::Debug {
    /// Wire id (disk v3 record tag — one of [`CODEC_F32`] /
    /// [`CODEC_F16`] / [`CODEC_INT8`]).
    fn id(&self) -> u8;

    /// CLI / metrics name (`f32` / `f16` / `int8`).
    fn name(&self) -> &'static str;

    /// Encoded payload size in bytes for a block of `n_elems` f32
    /// elements (exact, not an estimate — budget accounting uses it).
    fn encoded_len(&self, n_elems: usize) -> usize;

    /// Encode one logical block payload. Never panics: non-finite
    /// elements are sanitized per codec.
    fn encode_block(&self, src: &[f32]) -> Vec<u8>;

    /// Decode a whole payload into `dst` (`dst.len()` must match the
    /// element count the payload was encoded from). Errors are
    /// corruption verdicts, never panics.
    fn decode_block(&self, payload: &[u8], dst: &mut [f32]) -> Result<()>;

    /// Decode `dst.len()` elements starting at logical element
    /// `elem_offset` — the assemble read path, so sparse gathers never
    /// decode a whole block to read one channel span.
    fn decode_span(&self, payload: &[u8], elem_offset: usize,
                   dst: &mut [f32]) -> Result<()>;

    fn stats(&self) -> &CodecStats;
}

/// Build a fresh codec instance (own stats) for one serving stack.
/// Share the returned `Arc` between the host pool and the disk tier so
/// the stats aggregate across tiers.
pub fn codec_for(kind: KvCodecKind) -> Arc<dyn KvCodec> {
    match kind {
        KvCodecKind::F32 => Arc::new(LosslessF32::default()),
        KvCodecKind::F16 => Arc::new(F16Codec::default()),
        KvCodecKind::Int8 => Arc::new(Int8BlockCodec::default()),
    }
}

/// Process-wide fallback instance per wire id, for decoding records
/// written under a codec other than the session's configured one
/// (e.g. v2 lossless files read into an int8-configured cache). Their
/// stats are not surfaced; the active codec's are.
pub fn codec_by_id(id: u8) -> Option<Arc<dyn KvCodec>> {
    static F32C: OnceLock<Arc<dyn KvCodec>> = OnceLock::new();
    static F16C: OnceLock<Arc<dyn KvCodec>> = OnceLock::new();
    static INT8C: OnceLock<Arc<dyn KvCodec>> = OnceLock::new();
    match id {
        CODEC_F32 => Some(Arc::clone(
            F32C.get_or_init(|| Arc::new(LosslessF32::default())))),
        CODEC_F16 => Some(Arc::clone(
            F16C.get_or_init(|| Arc::new(F16Codec::default())))),
        CODEC_INT8 => Some(Arc::clone(
            INT8C.get_or_init(|| Arc::new(Int8BlockCodec::default())))),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// LosslessF32
// ---------------------------------------------------------------------------

/// Raw little-endian f32 bytes — byte-identical round trip, including
/// NaN payload bits. The default codec and the decoder for every v2
/// disk record.
#[derive(Debug, Default)]
pub struct LosslessF32 {
    stats: CodecStats,
}

impl KvCodec for LosslessF32 {
    fn id(&self) -> u8 {
        CODEC_F32
    }

    fn name(&self) -> &'static str {
        "f32"
    }

    fn encoded_len(&self, n_elems: usize) -> usize {
        n_elems * 4
    }

    fn encode_block(&self, src: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(src.len() * 4);
        for &x in src {
            out.extend_from_slice(&x.to_le_bytes());
        }
        self.stats.note_encode(src.len(), out.len());
        out
    }

    fn decode_block(&self, payload: &[u8], dst: &mut [f32]) -> Result<()> {
        if payload.len() != dst.len() * 4 {
            bail!("f32 payload length {} != {} elements * 4",
                  payload.len(), dst.len());
        }
        let t = Instant::now();
        for (d, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        self.stats.note_decode(t.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    fn decode_span(&self, payload: &[u8], elem_offset: usize,
                   dst: &mut [f32]) -> Result<()> {
        let start = elem_offset * 4;
        let end = start + dst.len() * 4;
        if end > payload.len() {
            bail!("f32 span {}..{} out of payload ({} bytes)", start, end,
                  payload.len());
        }
        let t = Instant::now();
        for (d, c) in
            dst.iter_mut().zip(payload[start..end].chunks_exact(4))
        {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        self.stats.note_decode(t.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    fn stats(&self) -> &CodecStats {
        &self.stats
    }
}

// ---------------------------------------------------------------------------
// F16Codec
// ---------------------------------------------------------------------------

/// Largest finite half-precision magnitude.
const F16_MAX: f32 = 65504.0;

/// f32 → IEEE half bits, round-to-nearest-even. Non-finite inputs
/// sanitize to (signed) zero, finite magnitudes clamp to ±65504 — the
/// encoder can therefore never produce an inf/NaN exponent.
fn f32_to_f16_bits(x: f32) -> u16 {
    let x = if x.is_finite() {
        x.clamp(-F16_MAX, F16_MAX)
    } else {
        0.0
    };
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let mant = bits & 0x7f_ffff;
    if exp < -25 {
        // underflows past half the smallest subnormal: signed zero
        return sign;
    }
    if exp < -14 {
        // half subnormal: explicit leading 1, round half up on the
        // shifted-out bits (value = m16 * 2^-24)
        let mant = mant | 0x80_0000;
        let shift = (-1 - exp) as u32; // 14..=24
        let m16 = ((mant >> (shift - 1)) + 1) >> 1;
        if m16 >= 0x400 {
            // rounding carried into the smallest normal
            return sign | (1 << 10);
        }
        return sign | m16 as u16;
    }
    // normal: 10-bit mantissa, round-to-nearest-even on bit 12
    let mut e = (exp + 15) as u32;
    let mut m = mant >> 13;
    let round_bit = (mant >> 12) & 1;
    let sticky = mant & 0xfff;
    if round_bit == 1 && (sticky != 0 || (m & 1) == 1) {
        m += 1;
        if m == 0x400 {
            m = 0;
            e += 1; // cannot reach 31: inputs are clamped to ±65504
        }
    }
    sign | ((e as u16) << 10) | (m as u16)
}

/// IEEE half bits → f32. The inf/NaN exponent is never produced by
/// [`f32_to_f16_bits`], but a corrupt byte could carry it: decode
/// defensively to a finite value (±65504, or 0.0 for NaN payloads).
fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let e = ((h >> 10) & 0x1f) as i32;
    let m = (h & 0x3ff) as f32;
    if e == 0 {
        sign * m * 2f32.powi(-24)
    } else if e == 31 {
        if m == 0.0 { sign * F16_MAX } else { 0.0 }
    } else {
        sign * (1.0 + m / 1024.0) * 2f32.powi(e - 15)
    }
}

/// IEEE half precision, 2 bytes per element.
#[derive(Debug, Default)]
pub struct F16Codec {
    stats: CodecStats,
}

impl KvCodec for F16Codec {
    fn id(&self) -> u8 {
        CODEC_F16
    }

    fn name(&self) -> &'static str {
        "f16"
    }

    fn encoded_len(&self, n_elems: usize) -> usize {
        n_elems * 2
    }

    fn encode_block(&self, src: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(src.len() * 2);
        for &x in src {
            out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
        self.stats.note_encode(src.len(), out.len());
        out
    }

    fn decode_block(&self, payload: &[u8], dst: &mut [f32]) -> Result<()> {
        if payload.len() != dst.len() * 2 {
            bail!("f16 payload length {} != {} elements * 2",
                  payload.len(), dst.len());
        }
        let t = Instant::now();
        for (d, c) in dst.iter_mut().zip(payload.chunks_exact(2)) {
            *d = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
        self.stats.note_decode(t.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    fn decode_span(&self, payload: &[u8], elem_offset: usize,
                   dst: &mut [f32]) -> Result<()> {
        let start = elem_offset * 2;
        let end = start + dst.len() * 2;
        if end > payload.len() {
            bail!("f16 span {}..{} out of payload ({} bytes)", start, end,
                  payload.len());
        }
        let t = Instant::now();
        for (d, c) in
            dst.iter_mut().zip(payload[start..end].chunks_exact(2))
        {
            *d = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
        self.stats.note_decode(t.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    fn stats(&self) -> &CodecStats {
        &self.stats
    }
}

// ---------------------------------------------------------------------------
// Int8BlockCodec
// ---------------------------------------------------------------------------

/// Per-block absmax int8 quantization. Payload layout:
/// `scale f32 le (4 bytes), n × i8`. The scale is `absmax / 127` over
/// the block's **finite** elements (0.0 for an all-zero or all-NaN
/// block — everything then decodes to exact 0.0); non-finite elements
/// quantize to 0. The scale rides inside the payload, so on disk it
/// sits under the record's FNV-1a checksum like every other byte.
#[derive(Debug, Default)]
pub struct Int8BlockCodec {
    stats: CodecStats,
}

impl KvCodec for Int8BlockCodec {
    fn id(&self) -> u8 {
        CODEC_INT8
    }

    fn name(&self) -> &'static str {
        "int8"
    }

    fn encoded_len(&self, n_elems: usize) -> usize {
        4 + n_elems
    }

    fn encode_block(&self, src: &[f32]) -> Vec<u8> {
        let absmax = src
            .iter()
            .filter(|x| x.is_finite())
            .fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = absmax / 127.0;
        let mut out = Vec::with_capacity(4 + src.len());
        out.extend_from_slice(&scale.to_le_bytes());
        for &x in src {
            let q = if scale > 0.0 && x.is_finite() {
                (x / scale).round().clamp(-127.0, 127.0) as i8
            } else {
                0
            };
            out.push(q as u8);
        }
        self.stats.note_encode(src.len(), out.len());
        out
    }

    fn decode_block(&self, payload: &[u8], dst: &mut [f32]) -> Result<()> {
        if payload.len() != dst.len() + 4 {
            bail!("int8 payload length {} != {} elements + 4 scale bytes",
                  payload.len(), dst.len());
        }
        let scale = f32::from_le_bytes([payload[0], payload[1], payload[2],
                                        payload[3]]);
        if !scale.is_finite() {
            bail!("corrupt int8 block scale {scale}");
        }
        let t = Instant::now();
        for (d, &b) in dst.iter_mut().zip(&payload[4..]) {
            *d = (b as i8) as f32 * scale;
        }
        self.stats.note_decode(t.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    fn decode_span(&self, payload: &[u8], elem_offset: usize,
                   dst: &mut [f32]) -> Result<()> {
        if payload.len() < 4 {
            bail!("int8 payload too short for its scale");
        }
        let start = 4 + elem_offset;
        let end = start + dst.len();
        if end > payload.len() {
            bail!("int8 span {}..{} out of payload ({} bytes)",
                  elem_offset, elem_offset + dst.len(), payload.len());
        }
        let scale = f32::from_le_bytes([payload[0], payload[1], payload[2],
                                        payload[3]]);
        if !scale.is_finite() {
            bail!("corrupt int8 block scale {scale}");
        }
        let t = Instant::now();
        for (d, &b) in dst.iter_mut().zip(&payload[start..end]) {
            *d = (b as i8) as f32 * scale;
        }
        self.stats.note_decode(t.elapsed().as_secs_f64() * 1e3);
        Ok(())
    }

    fn stats(&self) -> &CodecStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn all_codecs() -> Vec<Arc<dyn KvCodec>> {
        vec![
            codec_for(KvCodecKind::F32),
            codec_for(KvCodecKind::F16),
            codec_for(KvCodecKind::Int8),
        ]
    }

    #[test]
    fn ids_and_names_are_wire_stable() {
        let cs = all_codecs();
        assert_eq!(
            cs.iter().map(|c| c.id()).collect::<Vec<_>>(),
            vec![CODEC_F32, CODEC_F16, CODEC_INT8]
        );
        assert_eq!(
            cs.iter().map(|c| c.name()).collect::<Vec<_>>(),
            vec!["f32", "f16", "int8"]
        );
        for c in &cs {
            assert_eq!(codec_by_id(c.id()).unwrap().id(), c.id());
        }
        assert!(codec_by_id(99).is_none());
    }

    #[test]
    fn encoded_len_matches_payload_and_ratio() {
        let src: Vec<f32> = (0..256).map(|i| i as f32 * 0.37 - 40.0)
            .collect();
        for c in all_codecs() {
            let p = c.encode_block(&src);
            assert_eq!(p.len(), c.encoded_len(src.len()), "{}", c.name());
        }
        let n = 256;
        let logical = 4.0 * n as f32;
        let f16 = codec_for(KvCodecKind::F16);
        let int8 = codec_for(KvCodecKind::Int8);
        assert!(logical / f16.encoded_len(n) as f32 >= 1.9);
        assert!(logical / int8.encoded_len(n) as f32 >= 3.5);
    }

    #[test]
    fn f32_roundtrip_bit_identical_including_nan() {
        let c = codec_for(KvCodecKind::F32);
        let src = vec![0.0f32, -0.0, 1.5, -7.25e-30, 3.4e38, f32::NAN,
                       f32::INFINITY, f32::NEG_INFINITY];
        let p = c.encode_block(&src);
        let mut back = vec![0.0f32; src.len()];
        c.decode_block(&p, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.5), 0xc100);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        // clamp instead of overflowing into the inf exponent
        assert_eq!(f32_to_f16_bits(1e9), 0x7bff);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfbff);
        // smallest subnormal
        assert_eq!(f32_to_f16_bits(2f32.powi(-24)), 0x0001);
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0xc100), -2.5);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        assert_eq!(f16_bits_to_f32(0x0001), 2f32.powi(-24));
        // a corrupt inf/NaN exponent decodes finite, never propagates
        assert!(f16_bits_to_f32(0x7c00).is_finite());
        assert_eq!(f16_bits_to_f32(0x7e00), 0.0);
    }

    #[test]
    fn f16_roundtrip_within_half_precision() {
        let mut rng = Rng::new(7);
        let src: Vec<f32> = (0..512)
            .map(|_| (rng.next_f32() - 0.5) * 200.0)
            .collect();
        let c = codec_for(KvCodecKind::F16);
        let p = c.encode_block(&src);
        let mut back = vec![0.0f32; src.len()];
        c.decode_block(&p, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            // half a ulp of a 10-bit mantissa
            let tol = a.abs().max(2f32.powi(-14)) * 2f32.powi(-11) * 1.01;
            assert!((a - b).abs() <= tol, "{a} -> {b}");
        }
    }

    #[test]
    fn f16_subnormals_roundtrip_within_abs_tolerance() {
        let src: Vec<f32> = vec![2e-8, -3e-6, 5.5e-5, 2f32.powi(-24),
                                 -2f32.powi(-20), 1e-40, 0.0];
        let c = codec_for(KvCodecKind::F16);
        let p = c.encode_block(&src);
        let mut back = vec![0.0f32; src.len()];
        c.decode_block(&p, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= 2f32.powi(-24), "{a} -> {b}");
            assert!(b.is_finite());
        }
    }

    #[test]
    fn int8_roundtrip_within_half_scale() {
        let mut rng = Rng::new(11);
        let src: Vec<f32> = (0..512)
            .map(|_| (rng.next_f32() - 0.5) * 16.0)
            .collect();
        let absmax = src.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = absmax / 127.0;
        let c = codec_for(KvCodecKind::Int8);
        let p = c.encode_block(&src);
        let mut back = vec![0.0f32; src.len()];
        c.decode_block(&p, &mut back).unwrap();
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-7, "{a} -> {b}");
        }
    }

    #[test]
    fn absmax_zero_block_roundtrips_to_exact_zeros() {
        let src = vec![0.0f32; 64];
        let c = codec_for(KvCodecKind::Int8);
        let p = c.encode_block(&src);
        let mut back = vec![1.0f32; src.len()];
        c.decode_block(&p, &mut back).unwrap();
        assert!(back.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn non_finite_payloads_encode_and_decode_finite() {
        // NaN/±inf elements must never panic the encoder, and must
        // decode to finite values (the lossy codecs sanitize to 0)
        let src = vec![1.0f32, f32::NAN, -2.0, f32::INFINITY,
                       f32::NEG_INFINITY, 0.5];
        for kind in [KvCodecKind::F16, KvCodecKind::Int8] {
            let c = codec_for(kind);
            let p = c.encode_block(&src);
            let mut back = vec![0.0f32; src.len()];
            c.decode_block(&p, &mut back).unwrap();
            assert!(back.iter().all(|x| x.is_finite()), "{:?}", back);
            assert_eq!(back[1], 0.0, "{}", c.name());
            assert_eq!(back[3], 0.0, "{}", c.name());
            // the finite elements still carry signal: the scale comes
            // from finite absmax only
            assert!((back[0] - 1.0).abs() < 0.02, "{}", c.name());
            assert!((back[2] + 2.0).abs() < 0.02, "{}", c.name());
        }
        // an all-non-finite block decodes to exact zeros
        let junk = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        for kind in [KvCodecKind::F16, KvCodecKind::Int8] {
            let c = codec_for(kind);
            let p = c.encode_block(&junk);
            let mut back = vec![1.0f32; junk.len()];
            c.decode_block(&p, &mut back).unwrap();
            assert!(back.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn decode_span_matches_full_decode() {
        let mut rng = Rng::new(3);
        let src: Vec<f32> =
            (0..200).map(|_| (rng.next_f32() - 0.5) * 8.0).collect();
        for c in all_codecs() {
            let p = c.encode_block(&src);
            let mut full = vec![0.0f32; src.len()];
            c.decode_block(&p, &mut full).unwrap();
            for (off, len) in [(0usize, 7usize), (13, 50), (190, 10)] {
                let mut span = vec![0.0f32; len];
                c.decode_span(&p, off, &mut span).unwrap();
                assert_eq!(span, full[off..off + len], "{}", c.name());
            }
            // out-of-range span is an error, not a panic
            let mut over = vec![0.0f32; 10];
            assert!(c.decode_span(&p, 195, &mut over).is_err());
        }
    }

    #[test]
    fn truncated_payload_is_an_error_not_a_panic() {
        let src = vec![1.0f32; 32];
        for c in all_codecs() {
            let p = c.encode_block(&src);
            let mut dst = vec![0.0f32; src.len()];
            assert!(c.decode_block(&p[..p.len() - 1], &mut dst).is_err(),
                    "{}", c.name());
            assert!(c.decode_block(&[], &mut dst).is_err(), "{}",
                    c.name());
        }
    }

    #[test]
    fn corrupt_int8_scale_is_rejected() {
        let c = codec_for(KvCodecKind::Int8);
        let mut p = c.encode_block(&[1.0f32; 8]);
        p[..4].copy_from_slice(&f32::NAN.to_le_bytes());
        let mut dst = [0.0f32; 8];
        assert!(c.decode_block(&p, &mut dst).is_err());
        assert!(c.decode_span(&p, 0, &mut dst[..2]).is_err());
    }

    #[test]
    fn stats_track_bytes_and_drain_samples() {
        let c = codec_for(KvCodecKind::Int8);
        let src = vec![2.0f32; 60];
        let p = c.encode_block(&src);
        let mut dst = vec![0.0f32; src.len()];
        c.decode_block(&p, &mut dst).unwrap();
        c.decode_span(&p, 10, &mut dst[..5]).unwrap();
        let s = c.stats().snapshot(c.name());
        assert_eq!(s.codec, "int8");
        assert_eq!(s.blocks_encoded, 1);
        assert_eq!(s.blocks_decoded, 2);
        assert_eq!(s.logical_bytes, 240);
        assert_eq!(s.physical_bytes, 64);
        assert!((s.compression_ratio() - 240.0 / 64.0).abs() < 1e-9);
        assert_eq!(c.stats().take_decode_samples().len(), 2);
        assert!(c.stats().take_decode_samples().is_empty(), "drained");
        // fresh stats report a neutral ratio, not 0/0
        let fresh = codec_for(KvCodecKind::F16);
        assert_eq!(fresh.stats().snapshot("f16").compression_ratio(), 1.0);
    }
}
