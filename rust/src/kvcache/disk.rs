//! Persistent disk tier of the document cache: content-addressed,
//! per-hash cache files beneath the RAM tiers (see [`super`] for the
//! three-tier diagram).
//!
//! Each serialized [`DocEntry`] lives in its own file
//! (`doc_<hash:016x>.kv`) under the cache directory, so a restarted
//! server — or a host tier whose budget is smaller than the corpus —
//! re-serves previously-seen documents with **zero** model prefills.
//! The tier is thread-safe (one process-wide instance shared by every
//! engine through [`super::HostDocCache`]), keeps its own byte budget
//! with pluggable eviction, and never trusts what it reads back:
//!
//! # On-disk format (version 1, little-endian)
//!
//! ```text
//! magic    b"SKVD"                     4 bytes
//! version  u32                         4 bytes
//! hash     u64 (must match filename)   8 bytes
//! n_tokens u64                         8 bytes
//! tokens   n_tokens × i32
//! tensors  kv, attn, q_local — each: rank u32, dims u64×rank, f32 data
//! checksum u64 (FNV-1a over everything preceding it)
//! ```
//!
//! Files are written to a temp path and atomically renamed, so a crash
//! mid-write can never leave a half-entry under its content address.
//!
//! # Corruption / staleness contract
//!
//! A file that fails *any* validation — magic, version, filename/header
//! hash mismatch, checksum, truncation, implausible geometry — is
//! **quarantined** (moved into `quarantine/` inside the cache dir, or
//! deleted if even the rename fails), counted in
//! [`DiskStats::corrupt`], and reported as a miss: the caller falls
//! back to a model prefill and the request succeeds. Quarantined files
//! are never trusted again. A structurally valid file whose stored
//! token ids differ from the requested document (an FNV-1a hash
//! collision) is also a miss — counted in [`DiskStats::collisions`] —
//! but the file is left in place: it is correct for *its* document.

use std::collections::HashMap;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::tensor::Tensor;

use super::evict::{EvictionCandidate, EvictionPolicy, LruPolicy};
use super::store::{fnv64, DocEntry};

const MAGIC: [u8; 4] = *b"SKVD";
const VERSION: u32 = 1;
/// magic + version + hash + n_tokens.
const HEADER_LEN: usize = 24;
/// Upper bound on any decoded count (tokens, tensor dims/elements):
/// corrupt headers must not drive multi-gigabyte allocations.
const MAX_COUNT: u64 = 1 << 28;
/// Load-latency samples buffered until the next
/// [`DiskDocCache::take_load_samples`] drain.
const MAX_LOAD_SAMPLES: usize = 4096;

/// Disk-tier counters. All monotone lifetime totals except
/// `current_bytes` (what the directory holds right now).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DiskStats {
    /// Loads that returned a usable entry.
    pub hits: u64,
    /// Lookups that produced no entry (absent, corrupt, or collision).
    pub misses: u64,
    /// Entries written ([`DiskDocCache::store`] calls that hit disk;
    /// content-addressed re-stores of a present hash are skipped).
    pub spills: u64,
    /// Cache files read back (every hit is a load; corrupt and
    /// collision reads count here too).
    pub loads: u64,
    /// Files quarantined for failing validation (at scan or load).
    pub corrupt: u64,
    /// Structurally valid files whose token ids did not match the
    /// requested document (content-hash collision, served as a miss).
    pub collisions: u64,
    /// Files deleted by the byte-budget eviction loop.
    pub evictions: u64,
    /// Bytes currently on disk under the budget.
    pub current_bytes: usize,
}

struct DiskSlot {
    /// Serialized file size (budget accounting).
    bytes: usize,
    /// Document length in tokens (eviction recompute-cost proxy).
    tokens: usize,
    last_use: u64,
}

struct DiskInner {
    index: HashMap<u64, DiskSlot>,
    clock: u64,
    budget_bytes: usize,
    stats: DiskStats,
    load_ms: Vec<f64>,
}

/// The persistent tier: a directory of per-hash cache files with an
/// in-memory index, byte budget, and eviction. Shared process-wide
/// behind an `Arc` (attach with [`super::HostDocCache::with_disk`]).
pub struct DiskDocCache {
    dir: PathBuf,
    inner: Mutex<DiskInner>,
    policy: Box<dyn EvictionPolicy>,
}

impl DiskDocCache {
    /// Open (creating if needed) a cache directory with an LRU budget.
    pub fn open(dir: impl Into<PathBuf>, budget_bytes: usize)
                -> Result<DiskDocCache> {
        Self::open_with_policy(dir, budget_bytes, Box::new(LruPolicy))
    }

    /// [`Self::open`] with an explicit eviction policy. Scans the
    /// directory: valid entries are indexed (recency seeded from file
    /// mtime order), stale or corrupt files are quarantined, and
    /// leftover temp files from an interrupted writer are removed.
    pub fn open_with_policy(dir: impl Into<PathBuf>, budget_bytes: usize,
                            policy: Box<dyn EvictionPolicy>)
                            -> Result<DiskDocCache> {
        let dir = dir.into();
        fs::create_dir_all(&dir).with_context(
            || format!("create disk cache dir {}", dir.display()))?;
        let cache = DiskDocCache {
            dir,
            inner: Mutex::new(DiskInner {
                index: HashMap::new(),
                clock: 0,
                budget_bytes,
                stats: DiskStats::default(),
                load_ms: Vec::new(),
            }),
            policy,
        };
        cache.scan()?;
        Ok(cache)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().unwrap().budget_bytes
    }

    pub fn stats(&self) -> DiskStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.inner.lock().unwrap().index.contains_key(&hash)
    }

    /// Drain the load-latency samples (milliseconds) buffered since the
    /// previous drain — the engine feeds them into the metrics
    /// histogram after every admission wave.
    pub fn take_load_samples(&self) -> Vec<f64> {
        std::mem::take(&mut self.inner.lock().unwrap().load_ms)
    }

    fn entry_path(&self, hash: u64) -> PathBuf {
        self.dir.join(format!("doc_{hash:016x}.kv"))
    }

    /// Read one document back. `expect_tokens` are the requested
    /// document's token ids: a stored entry that fails the comparison
    /// is a hash collision and reads as a miss — the disk tier never
    /// serves another document's KV. Corrupt files are quarantined and
    /// read as misses (the caller prefills).
    pub fn load(&self, hash: u64, expect_tokens: &[i32])
                -> Option<Arc<DocEntry>> {
        {
            let mut g = self.inner.lock().unwrap();
            if !g.index.contains_key(&hash) {
                g.stats.misses += 1;
                return None;
            }
        }
        let path = self.entry_path(hash);
        let t = Instant::now();
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                // evicted (or externally removed) between the index
                // check and the read: drop the stale index entry
                let mut g = self.inner.lock().unwrap();
                if let Some(slot) = g.index.remove(&hash) {
                    g.stats.current_bytes =
                        g.stats.current_bytes.saturating_sub(slot.bytes);
                }
                g.stats.misses += 1;
                return None;
            }
        };
        let decoded = decode_entry(hash, &bytes);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let mut g = self.inner.lock().unwrap();
        g.stats.loads += 1;
        match decoded {
            Err(why) => {
                g.stats.corrupt += 1;
                g.stats.misses += 1;
                if let Some(slot) = g.index.remove(&hash) {
                    g.stats.current_bytes =
                        g.stats.current_bytes.saturating_sub(slot.bytes);
                }
                drop(g);
                self.quarantine(&path, &why);
                None
            }
            Ok(entry) => {
                if entry.tokens != expect_tokens {
                    g.stats.collisions += 1;
                    g.stats.misses += 1;
                    return None;
                }
                g.clock += 1;
                let clock = g.clock;
                if let Some(slot) = g.index.get_mut(&hash) {
                    slot.last_use = clock;
                }
                g.stats.hits += 1;
                if g.load_ms.len() < MAX_LOAD_SAMPLES {
                    g.load_ms.push(ms);
                }
                Some(Arc::new(entry))
            }
        }
    }

    /// Persist one document. Content-addressed: a hash already on disk
    /// is skipped (returns `Ok(false)`), so write-through inserts and
    /// later eviction spills of the same entry cost one write total.
    /// The file lands under its final name only after a complete
    /// temp-file write + atomic rename (per-writer unique temp name,
    /// so concurrent same-hash writers cannot race on it).
    pub fn store(&self, entry: &DocEntry) -> Result<bool> {
        {
            let g = self.inner.lock().unwrap();
            if g.index.contains_key(&entry.hash) {
                return Ok(false);
            }
        }
        static TMP_SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let seq =
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let buf = encode_entry(entry);
        let path = self.entry_path(entry.hash);
        let tmp = path.with_extension(format!("tmp{seq}"));
        fs::write(&tmp, &buf)
            .with_context(|| format!("write {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("rename into {}", path.display()))?;
        let doomed = {
            let mut g = self.inner.lock().unwrap();
            g.clock += 1;
            let clock = g.clock;
            let replaced = g.index.insert(entry.hash, DiskSlot {
                bytes: buf.len(),
                tokens: entry.tokens.len(),
                last_use: clock,
            });
            if let Some(old) = replaced {
                g.stats.current_bytes =
                    g.stats.current_bytes.saturating_sub(old.bytes);
            }
            g.stats.current_bytes += buf.len();
            g.stats.spills += 1;
            self.evict_to_budget_locked(&mut g)
        };
        self.remove_files(&doomed);
        Ok(true)
    }

    /// Delete every cache file (quarantine is kept). Lifetime counters
    /// survive; `current_bytes` resets.
    pub fn clear(&self) {
        let doomed: Vec<u64> = {
            let mut g = self.inner.lock().unwrap();
            g.stats.current_bytes = 0;
            g.index.drain().map(|(h, _)| h).collect()
        };
        self.remove_files(&doomed);
    }

    /// Unlink evicted entries' files — always *after* the index lock
    /// drops, so deletion I/O never stalls lookups (a load racing the
    /// unlink sees a clean index miss either way).
    fn remove_files(&self, hashes: &[u64]) {
        for &h in hashes {
            let _ = fs::remove_file(self.entry_path(h));
        }
    }

    /// Evict down to the byte budget; returns the victims' hashes so
    /// the caller can unlink their files once the lock drops.
    fn evict_to_budget_locked(&self, g: &mut DiskInner) -> Vec<u64> {
        let mut doomed = Vec::new();
        if g.stats.current_bytes <= g.budget_bytes {
            return doomed;
        }
        let mut candidates: Vec<EvictionCandidate> = g
            .index
            .iter()
            .map(|(&h, s)| EvictionCandidate {
                hash: h,
                bytes: s.bytes,
                last_use: s.last_use,
                recompute_cost: s.tokens,
            })
            .collect();
        while g.stats.current_bytes > g.budget_bytes && g.index.len() > 1 {
            let Some(victim) = self.policy.pick_victim(&candidates) else {
                break;
            };
            candidates.retain(|c| c.hash != victim);
            let Some(slot) = g.index.remove(&victim) else { break };
            g.stats.current_bytes =
                g.stats.current_bytes.saturating_sub(slot.bytes);
            g.stats.evictions += 1;
            doomed.push(victim);
        }
        doomed
    }

    /// Index the directory's existing entries; quarantine what cannot
    /// be trusted. Only the fixed-size header is validated here — the
    /// checksum over the full payload runs at [`Self::load`] time.
    fn scan(&self) -> Result<()> {
        // (hash, file bytes, n_tokens, mtime)
        let mut found: Vec<(u64, usize, usize, std::time::SystemTime)> =
            Vec::new();
        let mut bad: Vec<(PathBuf, String)> = Vec::new();
        for ent in fs::read_dir(&self.dir)? {
            let ent = ent?;
            let path = ent.path();
            if !ent.file_type()?.is_file() {
                continue; // quarantine/ subdir and friends
            }
            let name = ent.file_name();
            let name = name.to_string_lossy();
            if name.contains(".tmp") {
                // interrupted writer: never renamed, never trusted
                let _ = fs::remove_file(&path);
                continue;
            }
            let Some(hash) = parse_entry_name(&name) else { continue };
            match read_header(&path) {
                Ok(hdr) if hdr.hash == hash => {
                    let meta = ent.metadata()?;
                    let mtime = meta
                        .modified()
                        .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                    found.push((hash, meta.len() as usize, hdr.n_tokens,
                                mtime));
                }
                Ok(hdr) => bad.push((path, format!(
                    "filename/header hash mismatch (header {:016x})",
                    hdr.hash))),
                Err(why) => bad.push((path, why)),
            }
        }
        // seed recency from mtime order: oldest file = first to evict
        found.sort_by_key(|f| f.3);
        let doomed = {
            let mut g = self.inner.lock().unwrap();
            for (hash, bytes, tokens, _) in found {
                g.clock += 1;
                let clock = g.clock;
                g.index.insert(hash,
                               DiskSlot { bytes, tokens, last_use: clock });
                g.stats.current_bytes += bytes;
            }
            g.stats.corrupt += bad.len() as u64;
            // a budget tightened between runs applies immediately
            self.evict_to_budget_locked(&mut g)
        };
        self.remove_files(&doomed);
        for (path, why) in bad {
            self.quarantine(&path, &why);
        }
        Ok(())
    }

    /// Move an untrusted file out of the content-addressed namespace
    /// (deleting it if even that fails) so it can never be served.
    fn quarantine(&self, path: &Path, why: &str) {
        let qdir = self.dir.join("quarantine");
        let _ = fs::create_dir_all(&qdir);
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "entry".to_string());
        let mut dst = qdir.join(&name);
        let mut n = 1u32;
        while dst.exists() {
            dst = qdir.join(format!("{name}.{n}"));
            n += 1;
        }
        if fs::rename(path, &dst).is_err() {
            let _ = fs::remove_file(path);
        }
        crate::warn!("quarantined disk cache file {}: {}",
                     path.display(), why);
    }
}

impl std::fmt::Debug for DiskDocCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        f.debug_struct("DiskDocCache")
            .field("dir", &self.dir)
            .field("entries", &g.index.len())
            .field("budget_bytes", &g.budget_bytes)
            .field("stats", &g.stats)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Serialization (checksummed with the shared kvcache FNV-1a — see
// `store::fnv64`)
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    put_u32(buf, t.shape().len() as u32);
    for &d in t.shape() {
        put_u64(buf, d as u64);
    }
    for &x in t.data() {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn encode_entry(e: &DocEntry) -> Vec<u8> {
    let payload = (e.kv.numel() + e.attn.numel() + e.q_local.numel()) * 4;
    let mut buf =
        Vec::with_capacity(HEADER_LEN + e.tokens.len() * 4 + payload + 128);
    buf.extend_from_slice(&MAGIC);
    put_u32(&mut buf, VERSION);
    put_u64(&mut buf, e.hash);
    put_u64(&mut buf, e.tokens.len() as u64);
    for &t in &e.tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    put_tensor(&mut buf, &e.kv);
    put_tensor(&mut buf, &e.attn);
    put_tensor(&mut buf, &e.q_local);
    let sum = fnv64(&buf);
    put_u64(&mut buf, sum);
    buf
}

/// Bounds-checked little-endian reader over a byte slice; every error
/// is a corruption verdict, never a panic.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if n > self.b.len() - self.i {
            return Err(format!("truncated at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6],
                               s[7]]))
    }

    fn count(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u64()?;
        if n > MAX_COUNT {
            return Err(format!("implausible {what} count {n}"));
        }
        Ok(n as usize)
    }

    fn tensor(&mut self) -> Result<Tensor, String> {
        let rank = self.u32()? as usize;
        if rank > 8 {
            return Err(format!("implausible tensor rank {rank}"));
        }
        let mut shape = Vec::with_capacity(rank);
        let mut numel: u64 = 1;
        for _ in 0..rank {
            let d = self.count("dim")? as u64;
            numel = numel.saturating_mul(d.max(1));
            shape.push(d as usize);
        }
        if numel > MAX_COUNT {
            return Err(format!("implausible tensor size {numel}"));
        }
        let n: usize = shape.iter().product();
        let raw = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Tensor::new(shape, data).map_err(|e| format!("bad tensor: {e}"))
    }
}

struct Header {
    hash: u64,
    n_tokens: usize,
}

fn read_header(path: &Path) -> Result<Header, String> {
    let mut f = fs::File::open(path).map_err(|e| format!("open: {e}"))?;
    let mut hdr = [0u8; HEADER_LEN];
    f.read_exact(&mut hdr)
        .map_err(|_| "truncated header".to_string())?;
    parse_header(&hdr)
}

fn parse_header(hdr: &[u8]) -> Result<Header, String> {
    let mut rd = Rd { b: hdr, i: 0 };
    if rd.take(4)? != &MAGIC[..] {
        return Err("bad magic".to_string());
    }
    let version = rd.u32()?;
    if version != VERSION {
        return Err(format!("unsupported format version {version}"));
    }
    let hash = rd.u64()?;
    let n_tokens = rd.count("token")?;
    Ok(Header { hash, n_tokens })
}

/// Decode and fully validate one serialized entry (checksum, hash,
/// geometry). `Err` is the human-readable corruption reason.
fn decode_entry(expect_hash: u64, bytes: &[u8]) -> Result<DocEntry, String> {
    if bytes.len() < HEADER_LEN + 8 {
        return Err(format!("file too short ({} bytes)", bytes.len()));
    }
    let body_len = bytes.len() - 8;
    let mut tail = Rd { b: bytes, i: body_len };
    let stored_sum = tail.u64()?;
    if fnv64(&bytes[..body_len]) != stored_sum {
        return Err("checksum mismatch".to_string());
    }
    let hdr = parse_header(&bytes[..HEADER_LEN])?;
    if hdr.hash != expect_hash {
        return Err(format!("header hash {:016x} != expected {:016x}",
                           hdr.hash, expect_hash));
    }
    let mut rd = Rd { b: &bytes[..body_len], i: HEADER_LEN };
    let raw = rd.take(hdr.n_tokens * 4)?;
    let mut tokens = Vec::with_capacity(hdr.n_tokens);
    for c in raw.chunks_exact(4) {
        tokens.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let kv = rd.tensor()?;
    let attn = rd.tensor()?;
    let q_local = rd.tensor()?;
    if rd.i != body_len {
        return Err(format!("{} trailing bytes", body_len - rd.i));
    }
    let doc_bytes =
        kv.size_bytes() + attn.size_bytes() + q_local.size_bytes();
    Ok(DocEntry {
        hash: hdr.hash,
        tokens,
        kv,
        attn,
        q_local,
        bytes: doc_bytes,
    })
}

fn parse_entry_name(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("doc_")?.strip_suffix(".kv")?;
    if hex.len() != 16 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::super::store::doc_hash;
    use super::*;

    fn test_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "samkv-disk-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn entry(tokens: Vec<i32>) -> DocEntry {
        let n = tokens.len().max(1);
        let mut kv = Tensor::zeros(&[1, 2, 1, n, 2]);
        for (i, x) in kv.data_mut().iter_mut().enumerate() {
            *x = i as f32 * 0.5 - 1.0;
        }
        let attn = Tensor::full(&[1, 1, n, n], 0.25);
        let q_local = Tensor::full(&[1, 1, 2], -3.5);
        let bytes =
            kv.size_bytes() + attn.size_bytes() + q_local.size_bytes();
        DocEntry { hash: doc_hash(&tokens), tokens, kv, attn, q_local,
                   bytes }
    }

    #[test]
    fn roundtrip_preserves_entry() {
        let dir = test_dir("roundtrip");
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        let e = entry(vec![1, 2, 3]);
        assert!(cache.store(&e).unwrap());
        assert!(cache.contains(e.hash));
        let back = cache.load(e.hash, &[1, 2, 3]).expect("disk hit");
        assert_eq!(back.hash, e.hash);
        assert_eq!(back.tokens, e.tokens);
        assert_eq!(back.kv, e.kv);
        assert_eq!(back.attn, e.attn);
        assert_eq!(back.q_local, e.q_local);
        assert_eq!(back.bytes, e.bytes);
        let s = cache.stats();
        assert_eq!((s.spills, s.hits, s.loads, s.misses), (1, 1, 1, 0));
        assert!(s.current_bytes > 0);
        assert_eq!(cache.take_load_samples().len(), 1);
        assert!(cache.take_load_samples().is_empty(), "drained");
        // content-addressed: a second store of the same hash is skipped
        assert!(!cache.store(&e).unwrap());
        assert_eq!(cache.stats().spills, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn restart_scan_reindexes_entries() {
        let dir = test_dir("restart");
        let (h1, h2);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            let e1 = entry(vec![1, 2]);
            let e2 = entry(vec![3, 4, 5]);
            (h1, h2) = (e1.hash, e2.hash);
            cache.store(&e1).unwrap();
            cache.store(&e2).unwrap();
        }
        // "process restart": a fresh instance over the same directory
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(h1) && cache.contains(h2));
        assert!(cache.stats().current_bytes > 0);
        let back = cache.load(h2, &[3, 4, 5]).expect("warm restart hit");
        assert_eq!(back.tokens, vec![3, 4, 5]);
        assert_eq!(cache.stats().hits, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_quarantined_not_served() {
        let dir = test_dir("corrupt");
        let e = entry(vec![7, 8, 9]);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        // flip one payload byte: checksum must catch it at load time
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert!(cache.load(e.hash, &[7, 8, 9]).is_none(),
                "corrupt entry must read as a miss");
        let s = cache.stats();
        assert_eq!(s.corrupt, 1);
        assert_eq!(s.hits, 0);
        assert!(!path.exists(), "corrupt file must leave its address");
        assert!(fs::read_dir(dir.join("quarantine")).unwrap().count() >= 1,
                "corrupt file must be quarantined");
        assert!(!cache.contains(e.hash));
        // the address is reusable after quarantine
        assert!(cache.store(&e).unwrap());
        assert!(cache.load(e.hash, &[7, 8, 9]).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_header_quarantined_at_scan() {
        let dir = test_dir("trunchdr");
        let e = entry(vec![4, 4]);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..10]).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(cache.len(), 0, "truncated file must not be indexed");
        assert_eq!(cache.stats().corrupt, 1);
        assert!(!path.exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_version_quarantined_at_scan() {
        let dir = test_dir("stale");
        let e = entry(vec![6]);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let mut bytes = fs::read(&path).unwrap();
        bytes[4] = 99; // version field
        fs::write(&path, &bytes).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.stats().corrupt, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn collision_reads_as_miss_but_keeps_file() {
        let dir = test_dir("collide");
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        // forge a colliding address: entry stored under the hash of a
        // *different* document
        let victim_hash = doc_hash(&[1, 2, 3]);
        let mut other = entry(vec![9, 9]);
        other.hash = victim_hash;
        cache.store(&other).unwrap();
        assert!(cache.load(victim_hash, &[1, 2, 3]).is_none(),
                "collision must never serve another document's KV");
        let s = cache.stats();
        assert_eq!((s.collisions, s.misses, s.corrupt), (1, 1, 0));
        // the stored document itself still loads
        assert!(cache.load(victim_hash, &[9, 9]).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_eviction_deletes_files() {
        let dir = test_dir("budget");
        // each entry file is well over 100 bytes; budget of ~2 files
        let e1 = entry(vec![1; 8]);
        let one_file = encode_entry(&e1).len();
        let cache =
            DiskDocCache::open(&dir, one_file * 2 + one_file / 2).unwrap();
        cache.store(&e1).unwrap();
        cache.store(&entry(vec![2; 8])).unwrap();
        cache.store(&entry(vec![3; 8])).unwrap();
        let s = cache.stats();
        assert!(s.evictions >= 1, "over-budget store must evict");
        assert!(s.current_bytes <= cache.budget_bytes());
        assert_eq!(cache.len(), 2);
        // LRU: the first entry was the victim, and its file is gone
        assert!(!cache.contains(e1.hash));
        assert!(!dir.join(format!("doc_{:016x}.kv", e1.hash)).exists());
        assert!(cache.load(e1.hash, &[1; 8]).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn entry_name_parse() {
        let h = 0x0123456789abcdefu64;
        assert_eq!(parse_entry_name(&format!("doc_{h:016x}.kv")), Some(h));
        assert_eq!(parse_entry_name("doc_123.kv"), None);
        assert_eq!(parse_entry_name("doc_0123456789abcdef.tmp"), None);
        assert_eq!(parse_entry_name("readme.md"), None);
    }
}
