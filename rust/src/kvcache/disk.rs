//! Persistent disk tier of the document cache: content-addressed,
//! per-hash cache files beneath the RAM tiers (see [`super`] for the
//! three-tier diagram).
//!
//! Each document lives in its own file (`doc_<hash:016x>.kv`) under
//! the cache directory, so a restarted server — or a host tier whose
//! budget is smaller than the corpus — re-serves previously-seen
//! documents with **zero** model prefills. The tier is thread-safe
//! (one process-wide instance shared by every engine through
//! [`super::HostDocCache`]), keeps its own byte budget with pluggable
//! eviction (per-file — the file is the disk tier's eviction and
//! quarantine unit), and never trusts what it reads back.
//!
//! # On-disk format (version 3, little-endian)
//!
//! Since the paged block pool landed, a file stores the document's KV
//! as an **independently checksummed block list** — the disk mirror of
//! the pool's block granularity — instead of one monolithic tensor
//! blob under a single whole-file checksum. Version 3 adds a codec
//! tag to every record:
//!
//! ```text
//! header   magic b"SKVD", version u32, hash u64, n_tokens u64  24 bytes
//! geometry n_layers, n_heads, head_dim, kv_tokens,
//!          block_tokens, n_blocks, n_present — u32 each        28 bytes
//! tokens   n_tokens × i32
//! tensors  attn, q_local — each: rank u32, dims u64×rank, f32 data
//! meta checksum  u64 (FNV-1a over everything preceding it)
//! block record × n_present (ascending block index):
//!   index u32, len u32 (tokens), codec id u8, payload len u32,
//!   payload — the block's logical channel-major span encoded by the
//!   tagged codec (see [`super::codec`]; an int8 payload carries its
//!   own leading scale),
//!   record checksum u64 (FNV-1a over the record before it)
//! ```
//!
//! Records are **written** with the cache's configured codec
//! ([`DiskDocCache::with_codec`], default lossless f32) but **read**
//! by whatever codec each record names — a directory may freely mix
//! codecs across files and records, so `--kv-codec` can change
//! between runs without invalidating the cache. Version-2 files (the
//! untagged raw-f32 record format this one generalises) remain fully
//! readable, and the first merge-rewrite upgrades them to v3.
//!
//! A file may be **partial** (`n_present < n_blocks`): a host-tier
//! eviction pass spills only the victim blocks, and a later spill of
//! the same document *merges* into the existing file
//! ([`DiskDocCache::store_blocks`] reads, unions, and atomically
//! rewrites it) until it is complete — after which re-stores are
//! skipped (content-addressed: one write per block set). Files are
//! written to a temp path and atomically renamed, so a crash mid-write
//! can never leave a half-entry under its content address.
//!
//! # Corruption / staleness contract
//!
//! Validation is two-level, matching the format. A file whose
//! **metadata** fails — magic, version (a pre-pool version-1 blob
//! included), filename/header hash mismatch, meta checksum,
//! truncation, implausible geometry — is **quarantined** whole (moved
//! into `quarantine/`, or deleted if even the rename fails), counted
//! in [`DiskStats::corrupt`], and read as a miss. A file whose
//! metadata is sound but where an individual **block record** fails
//! its checksum (or is duplicated / out of range) loses *only that
//! block*: the bad record is skipped and counted in
//! [`DiskStats::corrupt_blocks`], the remaining blocks load normally,
//! and the caller refills the hole (prefill or re-spill) — one flipped
//! bit no longer poisons the whole document. A structurally valid file
//! whose stored token ids differ from the requested document (an
//! FNV-1a hash collision) is also a miss — counted in
//! [`DiskStats::collisions`] — but the file is left in place: it is
//! correct for *its* document.
//!
//! The `quarantine/` directory itself is bounded
//! ([`DiskDocCache::with_quarantine_cap`], default 64 MiB): when a
//! quarantine would push it over the cap, its oldest files are deleted
//! first, so a corrupt-heavy disk cannot grow it without limit.
//! [`DiskStats::quarantined_bytes`] gauges what it currently holds and
//! [`DiskStats::quarantine_drops`] counts the deletions.
//!
//! # I/O errors and the circuit breaker
//!
//! Corruption (above) is about bytes that *arrived* wrong; I/O errors
//! are reads/writes that failed outright — a flaky device, a detached
//! volume. A failed read is served as a miss (the index entry is kept:
//! the failure may be transient) and a failed write is logged and
//! skipped by the caller; both count in [`DiskStats::io_errors`]. With
//! a breaker configured ([`DiskDocCache::with_breaker`]), N
//! *consecutive* I/O errors open it: every lookup then short-circuits
//! to a miss and every writeback is skipped without touching the
//! device ([`DiskStats::breaker_short_circuits`]) — the tier degrades
//! to RAM-only instead of paying a failing device's latency per
//! request. After the probe interval one half-open operation is let
//! through: success re-closes the breaker, failure re-opens it. All
//! transitions count in [`DiskStats::breaker_opens`] /
//! [`DiskStats::breaker_closes`], and [`DiskStats::breaker_open`]
//! gauges the current state. Deterministic chaos tests drive these
//! paths with an injected [`crate::faultinject::FaultPlan`]
//! ([`DiskDocCache::with_faults`]).

use std::collections::HashMap;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::KvCodecKind;
use crate::faultinject::{FaultPlan, FaultSite};
use crate::sync::Mutex;
use crate::tensor::Tensor;

use super::breaker::{BreakerCore, BreakerStep};
use super::codec::{codec_by_id, codec_for, KvCodec};
use super::evict::{EvictionCandidate, EvictionPolicy, LruPolicy,
                   WHOLE_ENTRY};
use super::pool::{KvBlockPool, KvBlocks, KvLayout};
use super::store::{fnv64, DocEntry};

const MAGIC: [u8; 4] = *b"SKVD";
const VERSION: u32 = 3;
/// The previous format (untagged raw-f32 block records): still read,
/// never written.
const VERSION_V2: u32 = 2;
/// magic + version + hash + n_tokens.
const HEADER_LEN: usize = 24;
/// header + the seven u32 geometry fields — everything the restart
/// scan needs without reading payloads.
const SCAN_LEN: usize = HEADER_LEN + 28;
/// Upper bound on any decoded count (tokens, dims, block sizes):
/// corrupt headers must not drive multi-gigabyte allocations.
const MAX_COUNT: u64 = 1 << 28;
/// Load-latency samples buffered until the next
/// [`DiskDocCache::take_load_samples`] drain.
const MAX_LOAD_SAMPLES: usize = 4096;
/// Default byte cap on the `quarantine/` directory (oldest files are
/// deleted first once a quarantine would exceed it).
pub const DEFAULT_QUARANTINE_CAP_BYTES: usize = 64 << 20;

/// Disk-tier counters. All monotone lifetime totals except
/// `current_bytes` (what the directory holds right now).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DiskStats {
    /// Loads that returned usable data (a whole entry, possibly
    /// partial, or at least one refilled block).
    pub hits: u64,
    /// Lookups that produced nothing usable (absent, corrupt,
    /// collision, or no block the caller needed).
    pub misses: u64,
    /// Files written (fresh or merged-and-rewritten;
    /// content-addressed re-stores of a complete hash are skipped).
    pub spills: u64,
    /// Cache files read back (every hit is a load; corrupt and
    /// collision reads count here too).
    pub loads: u64,
    /// Files quarantined whole for failing metadata validation (at
    /// scan or load).
    pub corrupt: u64,
    /// Individual block records dropped for failing their own
    /// checksum (the rest of the file still served).
    pub corrupt_blocks: u64,
    /// Structurally valid files whose token ids did not match the
    /// requested document (content-hash collision, served as a miss).
    pub collisions: u64,
    /// Files deleted by the byte-budget eviction loop.
    pub evictions: u64,
    /// Total file bytes read back by the load paths (every counted
    /// `load` adds its file's size). Smaller codecs shrink this
    /// proportionally — the warm-restart I/O gauge.
    pub bytes_loaded: u64,
    /// Bytes currently on disk under the budget.
    pub current_bytes: usize,
    /// Reads/writes that failed outright (real or injected I/O
    /// errors — distinct from `corrupt`, which is bytes that arrived
    /// wrong). Consecutive ones trip the circuit breaker.
    pub io_errors: u64,
    /// Closed→open breaker transitions (threshold trips plus failed
    /// half-open probes re-opening).
    pub breaker_opens: u64,
    /// Open→closed transitions (successful half-open probes).
    pub breaker_closes: u64,
    /// Lookups/writebacks answered without touching the device
    /// because the breaker was open.
    pub breaker_short_circuits: u64,
    /// Gauge: 1 while the breaker is open or half-open, else 0.
    pub breaker_open: u64,
    /// Gauge: bytes currently held in `quarantine/` (under the cap).
    pub quarantined_bytes: u64,
    /// Quarantined files deleted oldest-first to hold the cap.
    pub quarantine_drops: u64,
}

struct DiskSlot {
    /// Serialized file size (budget accounting).
    bytes: usize,
    /// Document length in tokens (eviction recompute-cost proxy).
    tokens: usize,
    last_use: u64,
    /// All `n_blocks` records present and (as far as the last read
    /// saw) intact — complete files skip re-stores; incomplete ones
    /// accept merges.
    complete: bool,
}

struct DiskInner {
    index: HashMap<u64, DiskSlot>,
    clock: u64,
    budget_bytes: usize,
    stats: DiskStats,
    load_ms: Vec<f64>,
    /// Circuit-breaker state machine (pure core, model-checked in
    /// `tests/loom_models.rs`); lives under the single `disk-index`
    /// lock so the breaker adds no lock-order edge.
    breaker: BreakerCore,
}

/// The persistent tier: a directory of per-hash cache files with an
/// in-memory index, byte budget, and eviction. Shared process-wide
/// behind an `Arc` (attach with [`super::HostDocCache::with_disk`]).
pub struct DiskDocCache {
    dir: PathBuf,
    inner: Mutex<DiskInner>,
    policy: Box<dyn EvictionPolicy>,
    /// Codec for newly written records (reads honor each record's own
    /// tag regardless).
    codec: Arc<dyn KvCodec>,
    /// Epoch for the monotonic millisecond timestamps the pure
    /// [`BreakerCore`] consumes.
    epoch: Instant,
    /// Byte cap on the `quarantine/` directory.
    quarantine_cap_bytes: usize,
    /// Injected fault schedule (chaos testing); `None` in production.
    faults: Option<Arc<FaultPlan>>,
}

impl DiskDocCache {
    /// Open (creating if needed) a cache directory with an LRU budget.
    pub fn open(dir: impl Into<PathBuf>, budget_bytes: usize)
                -> Result<DiskDocCache> {
        Self::open_with_policy(dir, budget_bytes, Box::new(LruPolicy))
    }

    /// [`Self::open`] with an explicit eviction policy. Scans the
    /// directory: valid entries are indexed (recency seeded from file
    /// mtime order), stale or corrupt files are quarantined, and
    /// leftover temp files from an interrupted writer are removed.
    pub fn open_with_policy(dir: impl Into<PathBuf>, budget_bytes: usize,
                            policy: Box<dyn EvictionPolicy>)
                            -> Result<DiskDocCache> {
        let dir = dir.into();
        fs::create_dir_all(&dir).with_context(
            || format!("create disk cache dir {}", dir.display()))?;
        let cache = DiskDocCache {
            dir,
            inner: Mutex::named("disk-index", DiskInner {
                index: HashMap::new(),
                clock: 0,
                budget_bytes,
                stats: DiskStats::default(),
                load_ms: Vec::new(),
                breaker: BreakerCore::new(0, 500),
            }),
            policy,
            codec: codec_for(KvCodecKind::F32),
            epoch: Instant::now(),
            quarantine_cap_bytes: DEFAULT_QUARANTINE_CAP_BYTES,
            faults: None,
        };
        cache.scan()?;
        cache.enforce_quarantine_cap();
        Ok(cache)
    }

    /// Enable the I/O circuit breaker: `threshold` consecutive I/O
    /// errors open it (0 disables — the default for bare `open`;
    /// serving wires [`crate::config::ServingConfig`]'s default in),
    /// and after `probe` in the open state one half-open operation is
    /// admitted to test the device.
    pub fn with_breaker(self, threshold: usize, probe: Duration)
                        -> DiskDocCache {
        self.inner.lock().breaker =
            BreakerCore::new(threshold, probe.as_millis() as u64);
        self
    }

    /// Cap the `quarantine/` directory at `bytes` (oldest-first
    /// deletion past it; default [`DEFAULT_QUARANTINE_CAP_BYTES`]).
    pub fn with_quarantine_cap(mut self, bytes: usize) -> DiskDocCache {
        self.quarantine_cap_bytes = bytes;
        self.enforce_quarantine_cap();
        self
    }

    /// Attach a seeded fault schedule; the tier then pulls injected
    /// read/write errors, latency, and payload corruption from it at
    /// the sites its chaos tests assert on.
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> DiskDocCache {
        self.faults = Some(plan);
        self
    }

    /// Replace the codec used for newly **written** records (default
    /// lossless f32; share the serving stack's instance so its stats
    /// aggregate). Reading needs no configuration: every v3 record
    /// carries its own codec tag, decoded through this instance when
    /// the ids match or a process-wide fallback otherwise, and v2
    /// records are untagged raw f32.
    pub fn with_codec(mut self, codec: Arc<dyn KvCodec>) -> DiskDocCache {
        self.codec = codec;
        self
    }

    /// The codec newly written records are encoded with.
    pub fn codec(&self) -> &Arc<dyn KvCodec> {
        &self.codec
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().budget_bytes
    }

    pub fn stats(&self) -> DiskStats {
        self.inner.lock().stats.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.inner.lock().index.contains_key(&hash)
    }

    /// Drain the load-latency samples (milliseconds) buffered since the
    /// previous drain — the engine feeds them into the metrics
    /// histogram after every admission wave.
    pub fn take_load_samples(&self) -> Vec<f64> {
        std::mem::take(&mut self.inner.lock().load_ms)
    }

    fn entry_path(&self, hash: u64) -> PathBuf {
        self.dir.join(format!("doc_{hash:016x}.kv"))
    }

    /// True when the breaker is open or half-open right now.
    pub fn breaker_is_open(&self) -> bool {
        self.inner.lock().stats.breaker_open == 1
    }

    /// Milliseconds since this cache's epoch — the monotonic clock
    /// the pure [`BreakerCore`] consumes.
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Breaker gate, called before any disk I/O with the lock held:
    /// `true` means short-circuit (open, probe not yet due). An open
    /// breaker past its probe interval flips to half-open and lets
    /// this operation through as the probe.
    fn breaker_blocks_locked(&self, g: &mut DiskInner) -> bool {
        if g.breaker.blocks(self.now_ms()) {
            g.stats.breaker_short_circuits += 1;
            true
        } else {
            false
        }
    }

    /// Count one failed disk operation toward the breaker.
    fn note_io_error_locked(&self, g: &mut DiskInner) {
        g.stats.io_errors += 1;
        match g.breaker.note_error(self.now_ms()) {
            BreakerStep::NoChange => {}
            BreakerStep::Opened { failed_probe } => {
                g.stats.breaker_opens += 1;
                g.stats.breaker_open = 1;
                if !failed_probe {
                    crate::warn!(
                        "disk tier breaker OPEN after {} consecutive \
                         I/O errors ({})",
                        g.breaker.consecutive_errors(),
                        self.dir.display());
                }
            }
        }
    }

    /// Count one successful disk operation: resets the consecutive
    /// error run, and a half-open probe success re-closes the breaker.
    fn note_io_ok_locked(&self, g: &mut DiskInner) {
        if g.breaker.note_ok() {
            g.stats.breaker_closes += 1;
            g.stats.breaker_open = 0;
        }
    }

    /// Read the file behind `hash` (index-checked), decode its
    /// metadata, and apply the quarantine / collision verdicts. On
    /// success returns the decoded meta, the surviving block records,
    /// and the raw load latency.
    fn read_and_decode(&self, hash: u64, expect_tokens: &[i32])
                       -> Option<(Meta, Vec<(u32, Vec<f32>)>, f64)> {
        {
            let mut g = self.inner.lock();
            if self.breaker_blocks_locked(&mut g) {
                g.stats.misses += 1;
                return None;
            }
            if !g.index.contains_key(&hash) {
                g.stats.misses += 1;
                return None;
            }
        }
        if let Some(f) = &self.faults {
            if let Some(ms) = f.latency_ms(FaultSite::DiskLatency) {
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        let path = self.entry_path(hash);
        let t = Instant::now();
        let read = if self
            .faults
            .as_ref()
            .is_some_and(|f| f.should(FaultSite::DiskRead))
        {
            Err(std::io::Error::other("injected disk read fault"))
        } else {
            fs::read(&path)
        };
        let bytes = match read {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // evicted (or externally removed) between the index
                // check and the read: drop the stale index entry
                let mut g = self.inner.lock();
                if let Some(slot) = g.index.remove(&hash) {
                    g.stats.current_bytes =
                        g.stats.current_bytes.saturating_sub(slot.bytes);
                }
                g.stats.misses += 1;
                return None;
            }
            Err(e) => {
                // real (or injected) I/O error: possibly transient, so
                // the index entry is kept; the breaker counts it
                let mut g = self.inner.lock();
                self.note_io_error_locked(&mut g);
                g.stats.misses += 1;
                drop(g);
                crate::warn!("disk read failed for {hash:016x}: {e}");
                return None;
            }
        };
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let file_bytes = bytes.len() as u64;
        let meta = match decode_meta(hash, &bytes) {
            Ok(m) => m,
            Err(why) => {
                let mut g = self.inner.lock();
                self.note_io_ok_locked(&mut g);
                g.stats.loads += 1;
                g.stats.bytes_loaded += file_bytes;
                g.stats.corrupt += 1;
                g.stats.misses += 1;
                if let Some(slot) = g.index.remove(&hash) {
                    g.stats.current_bytes =
                        g.stats.current_bytes.saturating_sub(slot.bytes);
                }
                drop(g);
                self.quarantine(&path, &why);
                return None;
            }
        };
        if meta.tokens != expect_tokens {
            let mut g = self.inner.lock();
            self.note_io_ok_locked(&mut g);
            g.stats.loads += 1;
            g.stats.bytes_loaded += file_bytes;
            g.stats.collisions += 1;
            g.stats.misses += 1;
            return None;
        }
        let (mut blocks, mut bad) = decode_blocks(&meta.layout, &bytes,
                                                  meta.meta_end,
                                                  meta.version,
                                                  &self.codec);
        if !blocks.is_empty()
            && self
                .faults
                .as_ref()
                .is_some_and(|f| f.should(FaultSite::CodecDecode))
        {
            // injected codec failure: every record decodes as corrupt
            bad += blocks.len() as u64;
            blocks.clear();
        }
        let mut g = self.inner.lock();
        self.note_io_ok_locked(&mut g);
        g.stats.loads += 1;
        g.stats.bytes_loaded += file_bytes;
        if bad > 0 {
            g.stats.corrupt_blocks += bad;
            // the file lost records: accept a future merge-rewrite
            if let Some(slot) = g.index.get_mut(&hash) {
                slot.complete = false;
            }
        }
        Some((meta, blocks, ms))
    }

    /// Post-read accounting shared by the load paths.
    fn note_load_outcome(&self, hash: u64, usable: bool, ms: f64) {
        let mut g = self.inner.lock();
        if usable {
            g.clock += 1;
            let clock = g.clock;
            if let Some(slot) = g.index.get_mut(&hash) {
                slot.last_use = clock;
            }
            g.stats.hits += 1;
            if g.load_ms.len() < MAX_LOAD_SAMPLES {
                g.load_ms.push(ms);
            }
        } else {
            g.stats.misses += 1;
        }
    }

    /// Read one document back into `pool`-backed blocks.
    /// `expect_tokens` are the requested document's token ids: a
    /// stored entry that fails the comparison is a hash collision and
    /// reads as a miss — the disk tier never serves another document's
    /// KV. A file with missing or corrupt block records returns a
    /// **partial** entry (check
    /// [`KvBlocks::is_fully_resident`][super::pool::KvBlocks]); only
    /// metadata corruption quarantines the file and reads as a miss.
    pub fn load(&self, hash: u64, expect_tokens: &[i32],
                pool: &Arc<KvBlockPool>) -> Option<DocEntry> {
        let (meta, blocks, ms) =
            self.read_and_decode(hash, expect_tokens)?;
        let lay = meta.layout;
        let entry = if lay.block_tokens == pool.block_tokens() {
            // same block size as the pool: map records straight into
            // pool slots, holes stay holes
            let kv = KvBlocks::empty(pool, lay);
            let mut restored = false;
            for (b, data) in &blocks {
                if kv.restore_block(*b as usize, data).is_ok() {
                    restored = true;
                }
            }
            if !restored && lay.n_blocks() > 0 {
                self.note_load_outcome(hash, false, ms);
                return None;
            }
            // physical (post-codec) bytes, matching `from_parts`
            let bytes = kv.resident_bytes() + meta.attn.size_bytes()
                + meta.q_local.size_bytes();
            DocEntry {
                hash,
                tokens: meta.tokens,
                kv,
                attn: meta.attn,
                q_local: meta.q_local,
                bytes,
            }
        } else {
            // the file was written under a different --kv-block-tokens:
            // partial data cannot be re-blocked, but a complete file
            // re-blocks losslessly through the full tensor
            if blocks.len() != lay.n_blocks() {
                self.note_load_outcome(hash, false, ms);
                return None;
            }
            let kv = gather_logical(&lay, &blocks);
            match DocEntry::from_parts(pool, meta.tokens, kv, meta.attn,
                                       meta.q_local) {
                Ok(e) => e,
                Err(_) => {
                    self.note_load_outcome(hash, false, ms);
                    return None;
                }
            }
        };
        self.note_load_outcome(hash, true, ms);
        Some(entry)
    }

    /// Refill the **missing** blocks of an in-RAM entry from this
    /// hash's file (the partial-eviction warm path: the host tier
    /// kept the entry, only some blocks left). Geometry must match the
    /// file exactly — including `block_tokens`. Returns how many
    /// blocks were restored.
    pub fn load_blocks_into(&self, hash: u64, expect_tokens: &[i32],
                            kv: &KvBlocks) -> usize {
        let Some((meta, blocks, ms)) =
            self.read_and_decode(hash, expect_tokens)
        else {
            return 0;
        };
        if meta.layout != kv.layout() {
            self.note_load_outcome(hash, false, ms);
            return 0;
        }
        let mut restored = 0;
        for (b, data) in &blocks {
            if kv.restore_block(*b as usize, data).is_ok() {
                restored += 1;
            }
        }
        self.note_load_outcome(hash, restored > 0, ms);
        restored
    }

    /// Persist a document's blocks: the entry's **resident** blocks
    /// plus `extra` (payloads already extracted by an eviction pass —
    /// their slots may be gone). Content-addressed and merging: a
    /// complete file is skipped (`Ok(false)`), an incomplete one is
    /// read, unioned with the new blocks, and atomically rewritten —
    /// so repeated spills of one document converge on one complete
    /// file, each write landing via temp-file + rename (per-writer
    /// unique temp name, so concurrent same-hash writers cannot race).
    pub fn store_blocks(&self, entry: &DocEntry,
                        extra: &[(u32, Vec<f32>)]) -> Result<bool> {
        {
            // open breaker: skip the writeback without touching the
            // failing device (the document stays re-prefillable)
            let mut g = self.inner.lock();
            if self.breaker_blocks_locked(&mut g) {
                return Ok(false);
            }
        }
        let lay = entry.kv.layout();
        let mut have: HashMap<u32, Vec<f32>> = HashMap::new();
        for b in entry.kv.resident_block_indexes() {
            if let Some(d) = entry.kv.block_data(b as usize) {
                have.insert(b, d);
            }
        }
        for (b, d) in extra {
            have.entry(*b).or_insert_with(|| d.clone());
        }
        if have.is_empty() {
            return Ok(false);
        }
        let merge = {
            let g = self.inner.lock();
            match g.index.get(&entry.hash) {
                Some(s) if s.complete => return Ok(false),
                Some(_) => true,
                None => false,
            }
        };
        if merge {
            // union with the existing partial file's surviving records
            let path = self.entry_path(entry.hash);
            if let Ok(bytes) = fs::read(&path) {
                if let Ok(meta) = decode_meta(entry.hash, &bytes) {
                    if meta.layout == lay {
                        // any-version read; the rewrite below lands as
                        // v3 under our codec (v2 files upgrade here)
                        let (old, _) = decode_blocks(&lay, &bytes,
                                                     meta.meta_end,
                                                     meta.version,
                                                     &self.codec);
                        let news = have
                            .keys()
                            .any(|b| !old.iter().any(|(ob, _)| ob == b));
                        if !news {
                            return Ok(false);
                        }
                        for (b, d) in old {
                            have.entry(b).or_insert(d);
                        }
                    }
                    // geometry mismatch: overwrite with ours
                }
                // undecodable metadata: overwrite replaces it
            }
        }
        static TMP_SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let seq =
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut blocks: Vec<(u32, Vec<f32>)> = have.into_iter().collect();
        blocks.sort_by_key(|(b, _)| *b);
        let mut buf = encode_entry(entry.hash, &entry.tokens, &lay,
                                   &entry.attn, &entry.q_local, &blocks,
                                   &self.codec);
        if self
            .faults
            .as_ref()
            .is_some_and(|f| f.should(FaultSite::CorruptBlock))
        {
            // flip a byte inside the last block record (every record
            // is ≥ 21 bytes, so len-16 is always within it): read-back
            // must drop exactly that block via its record checksum
            let i = buf.len().saturating_sub(16);
            if let Some(byte) = buf.get_mut(i) {
                *byte ^= 0xff;
            }
        }
        let path = self.entry_path(entry.hash);
        let tmp = path.with_extension(format!("tmp{seq}"));
        let write = if self
            .faults
            .as_ref()
            .is_some_and(|f| f.should(FaultSite::DiskWrite))
        {
            Err(std::io::Error::other("injected disk write fault"))
        } else {
            fs::write(&tmp, &buf)
                .and_then(|()| fs::rename(&tmp, &path))
        };
        if let Err(e) = write {
            let _ = fs::remove_file(&tmp);
            let mut g = self.inner.lock();
            self.note_io_error_locked(&mut g);
            drop(g);
            return Err(e).with_context(
                || format!("write {}", path.display()));
        }
        let doomed = {
            let mut g = self.inner.lock();
            self.note_io_ok_locked(&mut g);
            g.clock += 1;
            let clock = g.clock;
            let replaced = g.index.insert(entry.hash, DiskSlot {
                bytes: buf.len(),
                tokens: entry.tokens.len(),
                last_use: clock,
                complete: blocks.len() == lay.n_blocks(),
            });
            if let Some(old) = replaced {
                g.stats.current_bytes =
                    g.stats.current_bytes.saturating_sub(old.bytes);
            }
            g.stats.current_bytes += buf.len();
            g.stats.spills += 1;
            self.evict_to_budget_locked(&mut g)
        };
        self.remove_files(&doomed);
        Ok(true)
    }

    /// Persist one document's resident blocks
    /// ([`Self::store_blocks`] with no extracted extras).
    pub fn store(&self, entry: &DocEntry) -> Result<bool> {
        self.store_blocks(entry, &[])
    }

    /// Delete every cache file (quarantine is kept). Lifetime counters
    /// survive; `current_bytes` resets.
    pub fn clear(&self) {
        let doomed: Vec<u64> = {
            let mut g = self.inner.lock();
            g.stats.current_bytes = 0;
            g.index.drain().map(|(h, _)| h).collect()
        };
        self.remove_files(&doomed);
    }

    /// Unlink evicted entries' files — always *after* the index lock
    /// drops, so deletion I/O never stalls lookups (a load racing the
    /// unlink sees a clean index miss either way).
    fn remove_files(&self, hashes: &[u64]) {
        for &h in hashes {
            let _ = fs::remove_file(self.entry_path(h));
        }
    }

    /// Evict down to the byte budget; returns the victims' hashes so
    /// the caller can unlink their files once the lock drops. The
    /// disk tier's eviction unit is the **file** (its quarantine and
    /// atomic-rename unit), so candidates are whole entries.
    fn evict_to_budget_locked(&self, g: &mut DiskInner) -> Vec<u64> {
        let mut doomed = Vec::new();
        if g.stats.current_bytes <= g.budget_bytes {
            return doomed;
        }
        let mut candidates: Vec<EvictionCandidate> = g
            .index
            .iter()
            .map(|(&h, s)| EvictionCandidate {
                hash: h,
                block: WHOLE_ENTRY,
                bytes: s.bytes,
                last_use: s.last_use,
                recompute_cost: s.tokens,
            })
            .collect();
        while g.stats.current_bytes > g.budget_bytes && g.index.len() > 1 {
            let Some(i) = self.policy.pick_victim(&candidates) else {
                break;
            };
            let victim = candidates.swap_remove(i).hash;
            let Some(slot) = g.index.remove(&victim) else { break };
            g.stats.current_bytes =
                g.stats.current_bytes.saturating_sub(slot.bytes);
            g.stats.evictions += 1;
            doomed.push(victim);
        }
        doomed
    }

    /// Index the directory's existing entries; quarantine what cannot
    /// be trusted. Only the fixed-size header + geometry prefix is
    /// validated here — checksums over the payloads run at load time.
    fn scan(&self) -> Result<()> {
        // (hash, file bytes, n_tokens, complete, mtime)
        let mut found: Vec<(u64, usize, usize, bool,
                            std::time::SystemTime)> = Vec::new();
        let mut bad: Vec<(PathBuf, String)> = Vec::new();
        for ent in fs::read_dir(&self.dir)? {
            let ent = ent?;
            let path = ent.path();
            if !ent.file_type()?.is_file() {
                continue; // quarantine/ subdir and friends
            }
            let name = ent.file_name();
            let name = name.to_string_lossy();
            if name.contains(".tmp") {
                // interrupted writer: never renamed, never trusted
                let _ = fs::remove_file(&path);
                continue;
            }
            let Some(hash) = parse_entry_name(&name) else { continue };
            match read_scan_header(&path) {
                Ok(hdr) if hdr.hash == hash => {
                    let meta = ent.metadata()?;
                    let mtime = meta
                        .modified()
                        .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                    found.push((hash, meta.len() as usize, hdr.n_tokens,
                                hdr.n_present == hdr.n_blocks, mtime));
                }
                Ok(hdr) => bad.push((path, format!(
                    "filename/header hash mismatch (header {:016x})",
                    hdr.hash))),
                Err(why) => bad.push((path, why)),
            }
        }
        // seed recency from mtime order: oldest file = first to evict
        found.sort_by_key(|f| f.4);
        let doomed = {
            let mut g = self.inner.lock();
            for (hash, bytes, tokens, complete, _) in found {
                g.clock += 1;
                let clock = g.clock;
                g.index.insert(hash, DiskSlot {
                    bytes,
                    tokens,
                    last_use: clock,
                    complete,
                });
                g.stats.current_bytes += bytes;
            }
            g.stats.corrupt += bad.len() as u64;
            // a budget tightened between runs applies immediately
            self.evict_to_budget_locked(&mut g)
        };
        self.remove_files(&doomed);
        for (path, why) in bad {
            self.quarantine(&path, &why);
        }
        Ok(())
    }

    /// Move an untrusted file out of the content-addressed namespace
    /// (deleting it if even that fails) so it can never be served.
    fn quarantine(&self, path: &Path, why: &str) {
        let qdir = self.dir.join("quarantine");
        let _ = fs::create_dir_all(&qdir);
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "entry".to_string());
        let mut dst = qdir.join(&name);
        let mut n = 1u32;
        while dst.exists() {
            dst = qdir.join(format!("{name}.{n}"));
            n += 1;
        }
        if fs::rename(path, &dst).is_err() {
            let _ = fs::remove_file(path);
        }
        crate::warn!("quarantined disk cache file {}: {}",
                     path.display(), why);
        self.enforce_quarantine_cap();
    }

    /// Hold `quarantine/` under its byte cap: oldest files (by mtime)
    /// are deleted first, and the `quarantined_bytes` gauge is
    /// refreshed from what actually remains on disk.
    fn enforce_quarantine_cap(&self) {
        let qdir = self.dir.join("quarantine");
        let Ok(entries) = fs::read_dir(&qdir) else {
            return; // no quarantine directory yet: gauge stays 0
        };
        let mut files: Vec<(std::time::SystemTime, PathBuf, u64)> =
            Vec::new();
        for ent in entries.flatten() {
            let Ok(meta) = ent.metadata() else { continue };
            if !meta.is_file() {
                continue;
            }
            let mtime = meta
                .modified()
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            files.push((mtime, ent.path(), meta.len()));
        }
        files.sort();
        let mut total: u64 = files.iter().map(|f| f.2).sum();
        let mut drops = 0u64;
        for (_, path, bytes) in &files {
            if total <= self.quarantine_cap_bytes as u64 {
                break;
            }
            if fs::remove_file(path).is_ok() {
                total -= bytes;
                drops += 1;
            }
        }
        let mut g = self.inner.lock();
        g.stats.quarantined_bytes = total;
        g.stats.quarantine_drops += drops;
    }
}

impl std::fmt::Debug for DiskDocCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock();
        f.debug_struct("DiskDocCache")
            .field("dir", &self.dir)
            .field("entries", &g.index.len())
            .field("budget_bytes", &g.budget_bytes)
            .field("stats", &g.stats)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Serialization (checksummed with the shared kvcache FNV-1a — see
// `store::fnv64`)
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    put_u32(buf, t.shape().len() as u32);
    for &d in t.shape() {
        put_u64(buf, d as u64);
    }
    for &x in t.data() {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize one document: checksummed metadata, then one
/// independently checksummed record per block (`blocks` sorted by
/// index, logical channel-major payloads), each encoded and tagged
/// with `codec`.
fn encode_entry(hash: u64, tokens: &[i32], lay: &KvLayout, attn: &Tensor,
                q_local: &Tensor, blocks: &[(u32, Vec<f32>)],
                codec: &Arc<dyn KvCodec>) -> Vec<u8> {
    let payload: usize =
        blocks.iter().map(|(_, d)| codec.encoded_len(d.len())).sum();
    let mut buf = Vec::with_capacity(
        SCAN_LEN + tokens.len() * 4
            + (attn.numel() + q_local.numel()) * 4
            + payload + blocks.len() * 24 + 128,
    );
    buf.extend_from_slice(&MAGIC);
    put_u32(&mut buf, VERSION);
    put_u64(&mut buf, hash);
    put_u64(&mut buf, tokens.len() as u64);
    put_u32(&mut buf, lay.n_layers as u32);
    put_u32(&mut buf, lay.n_heads as u32);
    put_u32(&mut buf, lay.head_dim as u32);
    put_u32(&mut buf, lay.n_tokens as u32);
    put_u32(&mut buf, lay.block_tokens as u32);
    put_u32(&mut buf, lay.n_blocks() as u32);
    put_u32(&mut buf, blocks.len() as u32);
    for &t in tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    put_tensor(&mut buf, attn);
    put_tensor(&mut buf, q_local);
    let meta_sum = fnv64(&buf);
    put_u64(&mut buf, meta_sum);
    for (b, data) in blocks {
        let start = buf.len();
        put_u32(&mut buf, *b);
        put_u32(&mut buf, lay.block_len(*b as usize) as u32);
        let enc = codec.encode_block(data);
        buf.push(codec.id());
        put_u32(&mut buf, enc.len() as u32);
        buf.extend_from_slice(&enc);
        let rec_sum = fnv64(&buf[start..]);
        put_u64(&mut buf, rec_sum);
    }
    buf
}

/// Bounds-checked little-endian reader over a byte slice; every error
/// is a corruption verdict, never a panic.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if n > self.b.len() - self.i {
            return Err(format!("truncated at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6],
                               s[7]]))
    }

    fn count(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u64()?;
        if n > MAX_COUNT {
            return Err(format!("implausible {what} count {n}"));
        }
        Ok(n as usize)
    }

    fn count32(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u32()? as u64;
        if n > MAX_COUNT {
            return Err(format!("implausible {what} count {n}"));
        }
        Ok(n as usize)
    }

    fn tensor(&mut self) -> Result<Tensor, String> {
        let rank = self.u32()? as usize;
        if rank > 8 {
            return Err(format!("implausible tensor rank {rank}"));
        }
        let mut shape = Vec::with_capacity(rank);
        let mut numel: u64 = 1;
        for _ in 0..rank {
            let d = self.count("dim")? as u64;
            numel = numel.saturating_mul(d.max(1));
            shape.push(d as usize);
        }
        if numel > MAX_COUNT {
            return Err(format!("implausible tensor size {numel}"));
        }
        let n: usize = shape.iter().product();
        let raw = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Tensor::new(shape, data).map_err(|e| format!("bad tensor: {e}"))
    }
}

/// The scan-time prefix: enough to index a file without reading its
/// payload.
struct ScanHeader {
    version: u32,
    hash: u64,
    n_tokens: usize,
    n_blocks: usize,
    n_present: usize,
}

fn read_scan_header(path: &Path) -> Result<ScanHeader, String> {
    let mut f = fs::File::open(path).map_err(|e| format!("open: {e}"))?;
    let mut hdr = [0u8; SCAN_LEN];
    f.read_exact(&mut hdr)
        .map_err(|_| "truncated header".to_string())?;
    parse_scan_header(&hdr)
}

fn parse_scan_header(hdr: &[u8]) -> Result<ScanHeader, String> {
    let mut rd = Rd { b: hdr, i: 0 };
    if rd.take(4)? != &MAGIC[..] {
        return Err("bad magic".to_string());
    }
    let version = rd.u32()?;
    if version != VERSION && version != VERSION_V2 {
        return Err(format!("unsupported format version {version}"));
    }
    let hash = rd.u64()?;
    let n_tokens = rd.count("token")?;
    let _n_layers = rd.count32("layer")?;
    let _n_heads = rd.count32("head")?;
    let _head_dim = rd.count32("head dim")?;
    let _kv_tokens = rd.count32("kv token")?;
    let _block_tokens = rd.count32("block token")?;
    let n_blocks = rd.count32("block")?;
    let n_present = rd.count32("present block")?;
    Ok(ScanHeader { version, hash, n_tokens, n_blocks, n_present })
}

/// Fully decoded metadata section of one file.
struct Meta {
    /// Format version (selects the block-record layout).
    version: u32,
    tokens: Vec<i32>,
    layout: KvLayout,
    attn: Tensor,
    q_local: Tensor,
    /// Offset just past the meta checksum — where block records begin.
    meta_end: usize,
}

/// Decode and validate the metadata section (everything up to and
/// including the meta checksum). `Err` is the human-readable reason
/// the **whole file** cannot be trusted (quarantine verdict).
fn decode_meta(expect_hash: u64, bytes: &[u8]) -> Result<Meta, String> {
    if bytes.len() < SCAN_LEN + 8 {
        return Err(format!("file too short ({} bytes)", bytes.len()));
    }
    let hdr = parse_scan_header(&bytes[..SCAN_LEN])?;
    if hdr.hash != expect_hash {
        return Err(format!("header hash {:016x} != expected {:016x}",
                           hdr.hash, expect_hash));
    }
    let mut rd = Rd { b: bytes, i: HEADER_LEN };
    let n_layers = rd.count32("layer")?;
    let n_heads = rd.count32("head")?;
    let head_dim = rd.count32("head dim")?;
    let kv_tokens = rd.count32("kv token")?;
    let block_tokens = rd.count32("block token")?;
    let n_blocks = rd.count32("block")?;
    let n_present = rd.count32("present block")?;
    if n_layers == 0 || n_heads == 0 || head_dim == 0 || block_tokens == 0
    {
        return Err("zero KV geometry".to_string());
    }
    let layout = KvLayout { n_layers, n_heads, head_dim,
                            n_tokens: kv_tokens, block_tokens };
    if (layout.per_token_elems() as u64)
        .saturating_mul(kv_tokens.max(1) as u64) > MAX_COUNT
    {
        return Err("implausible KV size".to_string());
    }
    if n_blocks != layout.n_blocks() || n_present > n_blocks {
        return Err(format!("inconsistent block counts {n_blocks}/\
                            {n_present} for {kv_tokens} tokens"));
    }
    let raw = rd.take(hdr.n_tokens * 4)?;
    let mut tokens = Vec::with_capacity(hdr.n_tokens);
    for c in raw.chunks_exact(4) {
        tokens.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let attn = rd.tensor()?;
    let q_local = rd.tensor()?;
    let body_end = rd.i;
    let stored_sum = rd.u64()?;
    if fnv64(&bytes[..body_end]) != stored_sum {
        return Err("meta checksum mismatch".to_string());
    }
    Ok(Meta { version: hdr.version, tokens, layout, attn, q_local,
              meta_end: rd.i })
}

/// Walk the block records after `start`, decoding each payload back
/// to logical f32 through the codec its tag names (`codec` when the
/// ids match, a process-wide fallback otherwise; `version` 2 records
/// are untagged raw f32). A record that fails its own checksum — or
/// is duplicated, out of range, or names an unknown codec — is
/// dropped alone; a record that cannot even be framed (truncation)
/// ends the walk, since record boundaries can no longer be trusted.
/// Returns the surviving `(index, logical payload)` records and how
/// many were dropped.
fn decode_blocks(lay: &KvLayout, bytes: &[u8], start: usize,
                 version: u32, codec: &Arc<dyn KvCodec>)
                 -> (Vec<(u32, Vec<f32>)>, u64) {
    let mut out: Vec<(u32, Vec<f32>)> = Vec::new();
    let mut bad = 0u64;
    let pte = lay.per_token_elems();
    let mut i = start;
    while i < bytes.len() {
        let rec_start = i;
        let mut rd = Rd { b: bytes, i };
        let Ok(b) = rd.u32() else { bad += 1; break };
        let Ok(len) = rd.u32() else { bad += 1; break };
        let (b, len) = (b as usize, len as usize);
        if version == VERSION_V2 {
            // v2 record: the payload length is implied by the block
            // geometry, so a bad index or length is unframeable and
            // ends the walk
            if b >= lay.n_blocks() || len != lay.block_len(b) {
                bad += 1;
                break;
            }
            let n = len * pte;
            let Ok(raw) = rd.take(n * 4) else { bad += 1; break };
            let data_end = rd.i;
            let Ok(stored_sum) = rd.u64() else { bad += 1; break };
            i = rd.i;
            if fnv64(&bytes[rec_start..data_end]) != stored_sum {
                bad += 1;
                continue;
            }
            if out.iter().any(|(ob, _)| *ob == b as u32) {
                bad += 1;
                continue;
            }
            let mut data = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            out.push((b as u32, data));
            continue;
        }
        // v3 record: the explicit payload length keeps framing intact
        // across any per-record verdict, so everything after the
        // prefix skips just this record
        let Ok(tag) = rd.take(1) else { bad += 1; break };
        let codec_id = tag[0];
        let Ok(payload_len) = rd.count32("payload") else {
            bad += 1;
            break;
        };
        let Ok(payload) = rd.take(payload_len) else { bad += 1; break };
        let data_end = rd.i;
        let Ok(stored_sum) = rd.u64() else { bad += 1; break };
        i = rd.i;
        if fnv64(&bytes[rec_start..data_end]) != stored_sum {
            bad += 1;
            continue;
        }
        if b >= lay.n_blocks()
            || len != lay.block_len(b)
            || out.iter().any(|(ob, _)| *ob == b as u32)
        {
            bad += 1;
            continue;
        }
        let dec = if codec.id() == codec_id {
            Some(Arc::clone(codec))
        } else {
            codec_by_id(codec_id)
        };
        let Some(dec) = dec else { bad += 1; continue };
        let mut data = vec![0f32; len * pte];
        if dec.decode_block(payload, &mut data).is_err() {
            bad += 1;
            continue;
        }
        out.push((b as u32, data));
    }
    (out, bad)
}

/// Rebuild the full `[L,2,H,T,Dh]` tensor from a complete logical
/// block set (the cross-`block_tokens` re-block path).
fn gather_logical(lay: &KvLayout, blocks: &[(u32, Vec<f32>)]) -> Tensor {
    let (dh, bt) = (lay.head_dim, lay.block_tokens);
    let nch = lay.n_layers * 2 * lay.n_heads;
    let t_all = lay.n_tokens;
    let mut out = Tensor::zeros(&[lay.n_layers, 2, lay.n_heads, t_all,
                                  dh]);
    let data = out.data_mut();
    for (b, blk) in blocks {
        let b = *b as usize;
        let len = lay.block_len(b);
        let t0 = b * bt;
        for ch in 0..nch {
            for t in 0..len {
                let src = ch * len * dh + t * dh;
                let dst = ch * t_all * dh + (t0 + t) * dh;
                data[dst..dst + dh]
                    .copy_from_slice(&blk[src..src + dh]);
            }
        }
    }
    out
}

/// Serialize one **complete** in-RAM entry into the v3 wire image —
/// the peer-RPC export path (see [`crate::server::peers`]). Returns
/// `None` when any block is non-resident: peers only exchange
/// complete entries, so the receiver can publish under its prefill
/// lease without a partial-entry state machine on the wire.
pub fn entry_to_bytes(entry: &DocEntry, codec: &Arc<dyn KvCodec>)
                      -> Option<Vec<u8>> {
    let lay = entry.kv.layout();
    let mut blocks: Vec<(u32, Vec<f32>)> = Vec::new();
    for b in entry.kv.resident_block_indexes() {
        blocks.push((b, entry.kv.block_data(b as usize)?));
    }
    if blocks.len() != lay.n_blocks() {
        return None;
    }
    blocks.sort_by_key(|(b, _)| *b);
    Some(encode_entry(entry.hash, &entry.tokens, &lay, &entry.attn,
                      &entry.q_local, &blocks, codec))
}

/// Decode a wire image (from a peer) straight into `pool`-backed
/// blocks — the read mirror of [`entry_to_bytes`], running the same
/// checksum / token-identity / geometry verdicts as a disk load
/// (cross-codec via the per-record tag, cross-`block_tokens` via the
/// logical re-block path). Returns `None` unless the image is
/// complete and verifies end-to-end: a damaged, truncated, or
/// hash-colliding peer payload is a miss, never a served entry.
pub fn entry_from_bytes(expect_hash: u64, expect_tokens: &[i32],
                        pool: &Arc<KvBlockPool>, bytes: &[u8])
                        -> Option<DocEntry> {
    let meta = decode_meta(expect_hash, bytes).ok()?;
    if meta.tokens.as_slice() != expect_tokens {
        return None; // collision: never serve another document's KV
    }
    let lay = meta.layout;
    let (blocks, _bad) = decode_blocks(&lay, bytes, meta.meta_end,
                                       meta.version, pool.codec());
    if blocks.len() != lay.n_blocks() {
        return None;
    }
    if lay.block_tokens == pool.block_tokens() {
        let kv = KvBlocks::empty(pool, lay);
        for (b, data) in &blocks {
            kv.restore_block(*b as usize, data).ok()?;
        }
        if !kv.is_fully_resident() {
            return None;
        }
        // physical (post-codec) bytes, matching `from_parts`
        let total = kv.resident_bytes() + meta.attn.size_bytes()
            + meta.q_local.size_bytes();
        Some(DocEntry {
            hash: expect_hash,
            tokens: meta.tokens,
            kv,
            attn: meta.attn,
            q_local: meta.q_local,
            bytes: total,
        })
    } else {
        // the sender ran a different --kv-block-tokens: re-block
        // losslessly through the full tensor
        let kv = gather_logical(&lay, &blocks);
        DocEntry::from_parts(pool, meta.tokens, kv, meta.attn,
                             meta.q_local)
            .ok()
    }
}

fn parse_entry_name(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("doc_")?.strip_suffix(".kv")?;
    if hex.len() != 16 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

/// Test support: rewrite one cache file in the **legacy v2 layout**
/// (untagged raw-f32 block records), decoding its payloads through
/// their codec tags first. Integration tests use this to fabricate a
/// pre-upgrade directory from a really-served document — the
/// production write path is v3-only, so there is no other way to
/// obtain a v2 file of live data.
#[doc(hidden)]
pub fn rewrite_file_as_v2(path: &Path) -> Result<()> {
    let bytes = fs::read(path).with_context(|| format!("read {path:?}"))?;
    let hdr = bytes
        .get(..SCAN_LEN)
        .ok_or_else(|| anyhow::anyhow!("file too short"))
        .and_then(|h| parse_scan_header(h).map_err(anyhow::Error::msg))?;
    let meta = decode_meta(hdr.hash, &bytes).map_err(anyhow::Error::msg)?;
    let codec = codec_for(KvCodecKind::F32);
    let (mut blocks, bad) = decode_blocks(&meta.layout, &bytes,
                                          meta.meta_end, meta.version,
                                          &codec);
    anyhow::ensure!(bad == 0, "{bad} undecodable records in {path:?}");
    blocks.sort_by_key(|(b, _)| *b);
    let lay = &meta.layout;
    let mut buf = Vec::new();
    buf.extend_from_slice(&MAGIC);
    put_u32(&mut buf, VERSION_V2);
    put_u64(&mut buf, hdr.hash);
    put_u64(&mut buf, meta.tokens.len() as u64);
    put_u32(&mut buf, lay.n_layers as u32);
    put_u32(&mut buf, lay.n_heads as u32);
    put_u32(&mut buf, lay.head_dim as u32);
    put_u32(&mut buf, lay.n_tokens as u32);
    put_u32(&mut buf, lay.block_tokens as u32);
    put_u32(&mut buf, lay.n_blocks() as u32);
    put_u32(&mut buf, blocks.len() as u32);
    for &t in &meta.tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    put_tensor(&mut buf, &meta.attn);
    put_tensor(&mut buf, &meta.q_local);
    let meta_sum = fnv64(&buf);
    put_u64(&mut buf, meta_sum);
    for (b, data) in &blocks {
        let start = buf.len();
        put_u32(&mut buf, *b);
        put_u32(&mut buf, lay.block_len(*b as usize) as u32);
        for &x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        let rec_sum = fnv64(&buf[start..]);
        put_u64(&mut buf, rec_sum);
    }
    fs::write(path, &buf).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::store::doc_hash;
    use super::*;

    fn test_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "samkv-disk-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn pool(bt: usize) -> Arc<KvBlockPool> {
        Arc::new(KvBlockPool::new(bt))
    }

    fn entry(pool: &Arc<KvBlockPool>, tokens: Vec<i32>) -> DocEntry {
        let n = tokens.len().max(1);
        let mut kv = Tensor::zeros(&[1, 2, 1, n, 2]);
        for (i, x) in kv.data_mut().iter_mut().enumerate() {
            *x = i as f32 * 0.5 - 1.0;
        }
        let attn = Tensor::full(&[1, 1, n, n], 0.25);
        let q_local = Tensor::full(&[1, 1, 2], -3.5);
        DocEntry::from_parts(pool, tokens, kv, attn, q_local).unwrap()
    }

    #[test]
    fn roundtrip_preserves_entry() {
        let dir = test_dir("roundtrip");
        let p = pool(64);
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        let e = entry(&p, vec![1, 2, 3]);
        assert!(cache.store(&e).unwrap());
        assert!(cache.contains(e.hash));
        let back = cache.load(e.hash, &[1, 2, 3], &p).expect("disk hit");
        assert_eq!(back.hash, e.hash);
        assert_eq!(back.tokens, e.tokens);
        assert!(back.kv.is_fully_resident());
        assert_eq!(back.kv.gather().unwrap(), e.kv.gather().unwrap());
        assert_eq!(back.attn, e.attn);
        assert_eq!(back.q_local, e.q_local);
        assert_eq!(back.bytes, e.bytes);
        let s = cache.stats();
        assert_eq!((s.spills, s.hits, s.loads, s.misses), (1, 1, 1, 0));
        assert_eq!((s.corrupt, s.corrupt_blocks), (0, 0));
        assert!(s.current_bytes > 0);
        assert_eq!(cache.take_load_samples().len(), 1);
        assert!(cache.take_load_samples().is_empty(), "drained");
        // content-addressed: a second store of a complete hash is
        // skipped
        assert!(!cache.store(&e).unwrap());
        assert_eq!(cache.stats().spills, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn restart_scan_reindexes_entries() {
        let dir = test_dir("restart");
        let p = pool(64);
        let (h1, h2);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            let e1 = entry(&p, vec![1, 2]);
            let e2 = entry(&p, vec![3, 4, 5]);
            (h1, h2) = (e1.hash, e2.hash);
            cache.store(&e1).unwrap();
            cache.store(&e2).unwrap();
        }
        // "process restart": a fresh instance over the same directory
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(h1) && cache.contains(h2));
        assert!(cache.stats().current_bytes > 0);
        let back =
            cache.load(h2, &[3, 4, 5], &p).expect("warm restart hit");
        assert_eq!(back.tokens, vec![3, 4, 5]);
        assert!(back.kv.is_fully_resident());
        assert_eq!(cache.stats().hits, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_metadata_quarantines_whole_file() {
        let dir = test_dir("metacorrupt");
        let p = pool(64);
        let e = entry(&p, vec![7, 8, 9]);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        // flip a byte inside the geometry prefix: the meta checksum
        // (or the count validation) must reject the whole file
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let mut bytes = fs::read(&path).unwrap();
        bytes[30] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert!(cache.load(e.hash, &[7, 8, 9], &p).is_none(),
                "corrupt metadata must read as a miss");
        let s = cache.stats();
        assert_eq!(s.corrupt, 1);
        assert_eq!(s.hits, 0);
        assert!(!path.exists(), "corrupt file must leave its address");
        assert!(fs::read_dir(dir.join("quarantine")).unwrap().count() >= 1,
                "corrupt file must be quarantined");
        assert!(!cache.contains(e.hash));
        // the address is reusable after quarantine
        assert!(cache.store(&e).unwrap());
        assert!(cache.load(e.hash, &[7, 8, 9], &p).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_block_quarantines_alone() {
        let dir = test_dir("blockcorrupt");
        // 2-token blocks over 5 kv tokens -> 3 records in the file
        let p = pool(2);
        let e = entry(&p, vec![1, 2, 3, 4, 5]);
        let full = e.kv.gather().unwrap();
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        // flip a byte inside the LAST block record's payload: its own
        // checksum rejects it, the other records must still serve
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let mut bytes = fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 12] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p)
            .expect("the intact blocks must still load");
        assert!(!back.kv.is_fully_resident());
        assert_eq!(back.kv.missing_block_indexes(), vec![2],
                   "only the corrupt record's block is lost");
        let s = cache.stats();
        assert_eq!(s.corrupt, 0, "block corruption is not file corruption");
        assert_eq!(s.corrupt_blocks, 1);
        assert_eq!(s.hits, 1);
        assert!(path.exists(), "the file keeps serving its good blocks");
        assert!(!dir.join("quarantine").exists());
        // the detected hole re-opens the file for writes: a re-store
        // of the intact entry heals it
        assert!(cache.store(&e).unwrap());
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p).unwrap();
        assert!(back.kv.is_fully_resident());
        assert_eq!(back.kv.gather().unwrap(), full);
        assert_eq!(cache.stats().corrupt_blocks, 1, "healed");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_spill_merges_to_complete_file() {
        let dir = test_dir("merge");
        let p = pool(2);
        let e = entry(&p, vec![1, 2, 3, 4, 5]);
        let full = e.kv.gather().unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        // evict the middle block from RAM, then spill: the file holds
        // the resident blocks {0,2} plus the extracted payload {1}...
        let d1 = e.kv.take_block_data(1).expect("resident block");
        assert!(cache.store(&e).unwrap()); // partial file {0, 2}
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p).unwrap();
        assert_eq!(back.kv.missing_block_indexes(), vec![1]);
        // ...and a later spill of the missing payload merges in
        assert!(cache.store_blocks(&e, &[(1, d1.clone())]).unwrap());
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p).unwrap();
        assert!(back.kv.is_fully_resident());
        assert_eq!(back.kv.gather().unwrap(), full);
        // complete file: further spills are skipped
        assert!(!cache.store_blocks(&e, &[(1, d1)]).unwrap());
        assert_eq!(cache.stats().spills, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_blocks_into_refills_holes() {
        let dir = test_dir("refill");
        let p = pool(2);
        let e = entry(&p, vec![1, 2, 3, 4, 5]);
        let full = e.kv.gather().unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        cache.store(&e).unwrap();
        // a partially evicted in-RAM entry refills just its holes
        e.kv.take_block_data(0);
        e.kv.take_block_data(2);
        assert_eq!(
            cache.load_blocks_into(e.hash, &[1, 2, 3, 4, 5], &e.kv), 2);
        assert!(e.kv.is_fully_resident());
        assert_eq!(e.kv.gather().unwrap(), full);
        assert_eq!(cache.stats().hits, 1);
        // nothing missing -> nothing restored, counted as a miss
        assert_eq!(
            cache.load_blocks_into(e.hash, &[1, 2, 3, 4, 5], &e.kv), 0);
        // geometry (block size) must match the file exactly
        let other = KvBlocks::empty(&pool(3), KvLayout {
            n_layers: 1, n_heads: 1, head_dim: 2, n_tokens: 5,
            block_tokens: 3,
        });
        assert_eq!(
            cache.load_blocks_into(e.hash, &[1, 2, 3, 4, 5], &other), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_reblocks_across_block_sizes() {
        let dir = test_dir("reblock");
        // written under 2-token blocks, read back into a 64-token pool:
        // a complete file re-blocks losslessly through the full tensor
        let p2 = pool(2);
        let e = entry(&p2, vec![1, 2, 3, 4, 5]);
        let full = e.kv.gather().unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        cache.store(&e).unwrap();
        let p64 = pool(64);
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p64).unwrap();
        assert!(back.kv.is_fully_resident());
        assert_eq!(back.kv.n_blocks(), 1);
        assert_eq!(back.kv.gather().unwrap(), full);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_header_quarantined_at_scan() {
        let dir = test_dir("trunchdr");
        let p = pool(64);
        let e = entry(&p, vec![4, 4]);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..10]).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(cache.len(), 0, "truncated file must not be indexed");
        assert_eq!(cache.stats().corrupt, 1);
        assert!(!path.exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_version_quarantined_at_scan() {
        let dir = test_dir("stale");
        let p = pool(64);
        let e = entry(&p, vec![6]);
        {
            let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
            cache.store(&e).unwrap();
        }
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        let mut bytes = fs::read(&path).unwrap();
        bytes[4] = 1; // a version-1 (pre-block-list) file
        fs::write(&path, &bytes).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(cache.len(), 0,
                   "pre-pool format files must never be decoded");
        assert_eq!(cache.stats().corrupt, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn collision_reads_as_miss_but_keeps_file() {
        let dir = test_dir("collide");
        let p = pool(64);
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        // forge a colliding address: entry stored under the hash of a
        // *different* document
        let victim_hash = doc_hash(&[1, 2, 3]);
        let mut other = entry(&p, vec![9, 9]);
        other.hash = victim_hash;
        cache.store(&other).unwrap();
        assert!(cache.load(victim_hash, &[1, 2, 3], &p).is_none(),
                "collision must never serve another document's KV");
        let s = cache.stats();
        assert_eq!((s.collisions, s.misses, s.corrupt), (1, 1, 0));
        // the stored document itself still loads
        assert!(cache.load(victim_hash, &[9, 9], &p).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_eviction_deletes_files() {
        let p = pool(64);
        // size one real file to derive a ~2-file budget
        let one_file = {
            let probe = test_dir("budget-probe");
            let cache = DiskDocCache::open(&probe, usize::MAX).unwrap();
            cache.store(&entry(&p, vec![1; 8])).unwrap();
            let n = cache.stats().current_bytes;
            let _ = fs::remove_dir_all(&probe);
            n
        };
        let dir = test_dir("budget");
        let e1 = entry(&p, vec![1; 8]);
        let cache =
            DiskDocCache::open(&dir, one_file * 2 + one_file / 2).unwrap();
        cache.store(&e1).unwrap();
        cache.store(&entry(&p, vec![2; 8])).unwrap();
        cache.store(&entry(&p, vec![3; 8])).unwrap();
        let s = cache.stats();
        assert!(s.evictions >= 1, "over-budget store must evict");
        assert!(s.current_bytes <= cache.budget_bytes());
        assert_eq!(cache.len(), 2);
        // LRU: the first entry was the victim, and its file is gone
        assert!(!cache.contains(e1.hash));
        assert!(!dir.join(format!("doc_{:016x}.kv", e1.hash)).exists());
        assert!(cache.load(e1.hash, &[1; 8], &p).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    /// An entry whose KV payload dwarfs its metadata (wide geometry,
    /// scalar attn), so file-size ratios measure the codec rather
    /// than the headers. Values are pseudo-random in [-1, 1).
    fn wide_entry(pool: &Arc<KvBlockPool>, tokens: Vec<i32>) -> DocEntry {
        let n = tokens.len().max(1);
        let mut kv = Tensor::zeros(&[2, 2, 2, n, 8]);
        let mut rng = crate::rng::Rng::new(0xd15c);
        for x in kv.data_mut() {
            *x = rng.next_f32() * 2.0 - 1.0;
        }
        let attn = Tensor::full(&[1, 1, 1, 1], 0.25);
        let q_local = Tensor::full(&[1, 1, 8], -3.5);
        DocEntry::from_parts(pool, tokens, kv, attn, q_local).unwrap()
    }

    fn assert_close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol, "{x} vs {y} (tol {tol})");
        }
    }

    /// Serialize `entry` in the retired v2 format (untagged raw-f32
    /// block records) — the fixture for backward-compat reads.
    fn encode_entry_v2(e: &DocEntry) -> Vec<u8> {
        let lay = e.kv.layout();
        let mut blocks: Vec<(u32, Vec<f32>)> = e
            .kv
            .resident_block_indexes()
            .into_iter()
            .map(|b| (b, e.kv.block_data(b as usize).unwrap()))
            .collect();
        blocks.sort_by_key(|(b, _)| *b);
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        put_u32(&mut buf, VERSION_V2);
        put_u64(&mut buf, e.hash);
        put_u64(&mut buf, e.tokens.len() as u64);
        put_u32(&mut buf, lay.n_layers as u32);
        put_u32(&mut buf, lay.n_heads as u32);
        put_u32(&mut buf, lay.head_dim as u32);
        put_u32(&mut buf, lay.n_tokens as u32);
        put_u32(&mut buf, lay.block_tokens as u32);
        put_u32(&mut buf, lay.n_blocks() as u32);
        put_u32(&mut buf, blocks.len() as u32);
        for &t in &e.tokens {
            buf.extend_from_slice(&t.to_le_bytes());
        }
        put_tensor(&mut buf, &e.attn);
        put_tensor(&mut buf, &e.q_local);
        let meta_sum = fnv64(&buf);
        put_u64(&mut buf, meta_sum);
        for (b, data) in &blocks {
            let start = buf.len();
            put_u32(&mut buf, *b);
            put_u32(&mut buf, lay.block_len(*b as usize) as u32);
            for &x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            let rec_sum = fnv64(&buf[start..]);
            put_u64(&mut buf, rec_sum);
        }
        buf
    }

    #[test]
    fn v3_roundtrip_under_lossy_codecs() {
        for (kind, tol) in [(KvCodecKind::F16, 1e-3f32),
                            (KvCodecKind::Int8, 0.5 / 127.0 + 1e-4)] {
            let dir = test_dir(&format!("v3-{}", kind.name()));
            let p = pool(64);
            let tokens: Vec<i32> = (0..128).collect();
            let e = wide_entry(&p, tokens.clone());
            let full = e.kv.gather().unwrap();
            let cache = DiskDocCache::open(&dir, usize::MAX)
                .unwrap()
                .with_codec(codec_for(kind));
            assert!(cache.store(&e).unwrap());
            let path = dir.join(format!("doc_{:016x}.kv", e.hash));
            let bytes = fs::read(&path).unwrap();
            assert_eq!(
                u32::from_le_bytes([bytes[4], bytes[5], bytes[6],
                                    bytes[7]]),
                VERSION,
                "files are written as v3");
            let back = cache.load(e.hash, &tokens, &p).expect("hit");
            assert!(back.kv.is_fully_resident());
            assert_close(&back.kv.gather().unwrap(), &full, tol);
            assert_eq!(back.attn, e.attn, "metadata stays raw f32");
            assert_eq!(cache.stats().corrupt_blocks, 0);
            let _ = fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn v2_file_loads_lossless_into_any_codec_cache() {
        let dir = test_dir("v2compat");
        fs::create_dir_all(&dir).unwrap();
        let p = pool(2);
        let e = entry(&p, vec![1, 2, 3, 4, 5]);
        let full = e.kv.gather().unwrap();
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        fs::write(&path, encode_entry_v2(&e)).unwrap();
        // even an int8-configured cache reads the v2 file bit-for-bit
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_codec(codec_for(KvCodecKind::Int8));
        assert_eq!(cache.len(), 1, "v2 files index at scan");
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p).unwrap();
        assert!(back.kv.is_fully_resident());
        assert_eq!(back.kv.gather().unwrap(), full,
                   "v2 records are untagged raw f32: lossless");
        let s = cache.stats();
        assert_eq!((s.corrupt, s.corrupt_blocks, s.hits), (0, 0, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_upgrades_v2_file_to_v3() {
        let dir = test_dir("v2upgrade");
        fs::create_dir_all(&dir).unwrap();
        let p = pool(2);
        let e = entry(&p, vec![1, 2, 3, 4, 5]);
        let full = e.kv.gather().unwrap();
        // a *partial* v2 file (blocks {0, 2} of 3) on disk...
        let d1 = e.kv.take_block_data(1).expect("resident block");
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        fs::write(&path, encode_entry_v2(&e)).unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_codec(codec_for(KvCodecKind::Int8));
        // ...merged with the missing payload rewrites as v3 under the
        // cache's codec
        assert!(cache.store_blocks(&e, &[(1, d1)]).unwrap());
        let bytes = fs::read(&path).unwrap();
        assert_eq!(
            u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]),
            VERSION,
            "merge-rewrite upgrades the file");
        let back = cache.load(e.hash, &[1, 2, 3, 4, 5], &p).unwrap();
        assert!(back.kv.is_fully_resident());
        // entry() values reach absmax 8.5, so int8 granularity is
        // 8.5/127 — compare within half a step
        assert_close(&back.kv.gather().unwrap(), &full,
                     0.5 * 8.5 / 127.0 + 1e-4);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lossy_codecs_shrink_files() {
        let p = pool(64);
        let e = wide_entry(&p, (0..128).collect());
        let mut sizes = Vec::new();
        for kind in [KvCodecKind::F32, KvCodecKind::F16,
                     KvCodecKind::Int8] {
            let dir = test_dir(&format!("shrink-{}", kind.name()));
            let cache = DiskDocCache::open(&dir, usize::MAX)
                .unwrap()
                .with_codec(codec_for(kind));
            assert!(cache.store(&e).unwrap());
            sizes.push(cache.stats().current_bytes as f64);
            let _ = fs::remove_dir_all(&dir);
        }
        assert!(sizes[0] >= sizes[1] * 1.9,
                "f16 files must be >=1.9x smaller ({sizes:?})");
        assert!(sizes[0] >= sizes[2] * 3.5,
                "int8 files must be >=3.5x smaller ({sizes:?})");
    }

    #[test]
    fn bytes_loaded_counts_file_reads() {
        let dir = test_dir("bytesloaded");
        let p = pool(64);
        let e = entry(&p, vec![1, 2, 3]);
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        cache.store(&e).unwrap();
        let size = cache.stats().current_bytes as u64;
        assert_eq!(cache.stats().bytes_loaded, 0, "writes don't count");
        cache.load(e.hash, &[1, 2, 3], &p).unwrap();
        assert_eq!(cache.stats().bytes_loaded, size);
        cache.load(e.hash, &[1, 2, 3], &p).unwrap();
        assert_eq!(cache.stats().bytes_loaded, size * 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rewrite_as_v2_downgrades_losslessly() {
        // the integration fixture helper: a stored v3 file (even one
        // written by a lossy cache) downgrades to a valid v2 file that
        // loads with byte-identical logical content
        let dir = test_dir("v2rewrite");
        let p = pool(64);
        let e = entry(&p, vec![4, 5, 6]);
        let full = e.kv.gather().unwrap();
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        cache.store(&e).unwrap();
        let path = dir.join(format!("doc_{:016x}.kv", e.hash));
        rewrite_file_as_v2(&path).unwrap();
        let bytes = fs::read(&path).unwrap();
        assert_eq!(
            u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]),
            VERSION_V2);
        let reread = DiskDocCache::open(&dir, usize::MAX).unwrap();
        assert_eq!(reread.len(), 1, "v2 file indexes at scan");
        let back = reread.load(e.hash, &[4, 5, 6], &p).unwrap();
        assert_eq!(back.kv.gather().unwrap(), full);
        assert_eq!(reread.stats().corrupt, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn entry_name_parse() {
        let h = 0x0123456789abcdefu64;
        assert_eq!(parse_entry_name(&format!("doc_{h:016x}.kv")), Some(h));
        assert_eq!(parse_entry_name("doc_123.kv"), None);
        assert_eq!(parse_entry_name("doc_0123456789abcdef.tmp"), None);
        assert_eq!(parse_entry_name("readme.md"), None);
    }

    #[test]
    fn breaker_opens_after_threshold_and_probe_recloses() {
        let dir = test_dir("breaker");
        let p = pool(64);
        let e = entry(&p, vec![1, 2, 3]);
        // first 1 read succeeds, then every read errors until the
        // plan's count runs out — deterministic breaker fuel
        let plan = Arc::new(
            FaultPlan::parse("disk_read:after=1:count=2").unwrap());
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_breaker(2, Duration::from_millis(30))
            .with_faults(plan);
        cache.store(&e).unwrap();
        assert!(cache.load(e.hash, &[1, 2, 3], &p).is_some());
        assert!(!cache.breaker_is_open());
        // two consecutive injected read errors trip the threshold
        assert!(cache.load(e.hash, &[1, 2, 3], &p).is_none());
        assert!(!cache.breaker_is_open(), "one error is not a trip");
        assert!(cache.load(e.hash, &[1, 2, 3], &p).is_none());
        assert!(cache.breaker_is_open());
        let s = cache.stats();
        assert_eq!((s.io_errors, s.breaker_opens), (2, 1));
        // open: lookups short-circuit to misses, writebacks skip
        assert!(cache.load(e.hash, &[1, 2, 3], &p).is_none());
        let e2 = entry(&p, vec![9, 9]);
        assert!(!cache.store(&e2).unwrap(), "open breaker skips writes");
        let s = cache.stats();
        assert_eq!(s.breaker_short_circuits, 2);
        assert_eq!(s.io_errors, 2, "short circuits touch no device");
        // past the probe interval the half-open probe succeeds (the
        // fault plan's count is exhausted) and re-closes the breaker
        std::thread::sleep(Duration::from_millis(40));
        assert!(cache.load(e.hash, &[1, 2, 3], &p).is_some(),
                "half-open probe must reach the device");
        assert!(!cache.breaker_is_open());
        let s = cache.stats();
        assert_eq!((s.breaker_opens, s.breaker_closes), (1, 1));
        assert_eq!(s.breaker_open, 0);
        // closed again: normal service resumed
        assert!(cache.store(&e2).unwrap());
        assert!(cache.load(e2.hash, &[9, 9], &p).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_probe_reopens_breaker() {
        let dir = test_dir("breakerprobe");
        let p = pool(64);
        let e = entry(&p, vec![4, 5]);
        // errors forever: the probe must fail and re-open
        let plan = Arc::new(FaultPlan::parse("disk_read").unwrap());
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_breaker(1, Duration::from_millis(20))
            .with_faults(plan);
        cache.store(&e).unwrap();
        assert!(cache.load(e.hash, &[4, 5], &p).is_none());
        assert!(cache.breaker_is_open());
        std::thread::sleep(Duration::from_millis(30));
        assert!(cache.load(e.hash, &[4, 5], &p).is_none(),
                "probe admitted but the device still fails");
        assert!(cache.breaker_is_open(), "failed probe re-opens");
        let s = cache.stats();
        assert_eq!(s.breaker_opens, 2);
        assert_eq!(s.breaker_closes, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_write_fault_errors_and_counts() {
        let dir = test_dir("writefault");
        let p = pool(64);
        let e = entry(&p, vec![6, 7]);
        let plan = Arc::new(
            FaultPlan::parse("disk_write:count=1").unwrap());
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_breaker(3, Duration::from_millis(50))
            .with_faults(plan);
        let err = cache.store(&e).unwrap_err().to_string();
        assert!(err.contains("write"), "{err}");
        let s = cache.stats();
        assert_eq!((s.io_errors, s.spills), (1, 0));
        assert!(!cache.contains(e.hash), "failed write indexes nothing");
        // count exhausted: the retry lands and resets the error run
        assert!(cache.store(&e).unwrap());
        assert!(cache.load(e.hash, &[6, 7], &p).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_corrupt_block_drops_one_block_on_readback() {
        let dir = test_dir("injectcorrupt");
        let p = pool(2); // 3 records for a 5-token doc
        let e = entry(&p, vec![1, 2, 3, 4, 5]);
        let plan = Arc::new(
            FaultPlan::parse("corrupt_block:count=1").unwrap());
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_faults(plan);
        cache.store(&e).unwrap();
        let back = cache
            .load(e.hash, &[1, 2, 3, 4, 5], &p)
            .expect("intact blocks still serve");
        assert_eq!(back.kv.missing_block_indexes(), vec![2],
                   "exactly the corrupted last record is lost");
        let s = cache.stats();
        assert_eq!((s.corrupt, s.corrupt_blocks), (0, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_codec_decode_fault_reads_as_incomplete() {
        let dir = test_dir("codecfault");
        let p = pool(64);
        let e = entry(&p, vec![3, 1, 4]);
        let plan = Arc::new(
            FaultPlan::parse("codec_decode:count=1").unwrap());
        let cache = DiskDocCache::open(&dir, usize::MAX)
            .unwrap()
            .with_faults(plan);
        cache.store(&e).unwrap();
        assert!(cache.load(e.hash, &[3, 1, 4], &p).is_none(),
                "all records corrupt -> nothing usable");
        let s = cache.stats();
        assert!(s.corrupt_blocks >= 1, "{s:?}");
        // the fault is spent; the file itself was never damaged
        assert!(cache.load(e.hash, &[3, 1, 4], &p).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantine_cap_deletes_oldest_and_gauges_bytes() {
        let dir = test_dir("qcap");
        let p = pool(64);
        let cache = DiskDocCache::open(&dir, usize::MAX).unwrap();
        // learn one file's size, then cap the quarantine to ~2 files
        let probe = entry(&p, vec![0]);
        cache.store(&probe).unwrap();
        let file_bytes = cache.stats().current_bytes;
        cache.clear();
        let cache = cache.with_quarantine_cap(file_bytes * 2 + 16);
        for i in 0..4i32 {
            let e = entry(&p, vec![i, i + 1]);
            cache.store(&e).unwrap();
            let path = dir.join(format!("doc_{:016x}.kv", e.hash));
            let mut bytes = fs::read(&path).unwrap();
            bytes[30] ^= 0xff; // metadata corruption -> quarantine
            fs::write(&path, &bytes).unwrap();
            assert!(cache.load(e.hash, &[i, i + 1], &p).is_none());
            // mtime granularity: keep oldest-first deterministic
            std::thread::sleep(Duration::from_millis(5));
        }
        let s = cache.stats();
        assert_eq!(s.corrupt, 4);
        assert!(s.quarantine_drops >= 2,
                "4 quarantined under a 2-file cap must drop: {s:?}");
        assert!(s.quarantined_bytes <= (file_bytes * 2 + 16) as u64,
                "gauge must sit under the cap: {s:?}");
        let held = fs::read_dir(dir.join("quarantine")).unwrap().count();
        assert!(held <= 2, "directory itself must be bounded: {held}");
        let _ = fs::remove_dir_all(&dir);
    }
}
