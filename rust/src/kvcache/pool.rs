//! Slot-based paged KV block pool: the storage substrate beneath every
//! RAM cache tier (see the [`super`] module docs for the tier diagram).
//!
//! # Slab / slot / block invariants
//!
//! A [`KvBlockPool`] owns **one contiguous `f32` slab** carved into
//! fixed-size **slots**. One slot holds one KV *block*: a
//! `--kv-block-tokens` span of a document's per-layer K/V, laid out
//! channel-major — for channel `ch = (l*2 + c) * n_heads + h` the
//! block's tokens occupy
//! `slab[slot_base + ch*block_tokens*head_dim ..][t_local*head_dim..]`,
//! zero-padded past a partial tail block. Every slot is the same size,
//! so freeing and reusing slots can never fragment the slab
//! (**zero external fragmentation**); the free list (`free_slots`) is a
//! plain LIFO vector with O(1) insert/remove, and an exhausted slab
//! **grows by doubling** (the existing prefix is preserved in place,
//! counted in [`PoolStats::grow_events`]).
//!
//! Slots are **refcounted**: a [`BlockRef`] is one reference; cloning
//! bumps the count, dropping releases it, and the slot returns to the
//! free list only at refcount zero. Allocation is **content-addressed**
//! (FNV-1a over the slot payload, verified byte-for-byte before
//! sharing — a hash collision can never alias two different blocks):
//! two documents or a forked session sharing a token prefix share the
//! underlying slots ([`PoolStats::share_hits`]), and an in-place write
//! through a shared ref copies first (**copy-on-write**,
//! [`BlockRef::write`]).
//!
//! The per-token element count is pinned by the first allocation
//! (every tier of one serving stack stores one model geometry); mixing
//! geometries in one pool is an error, never a corruption.
//!
//! [`KvBlocks`] is the document-side view: an indexable block list over
//! the pool replacing the old monolithic per-document KV tensor. Blocks
//! can be taken out (evicted/spilled) and restored individually, so a
//! partially evicted document keeps serving its resident blocks.
//!
//! # Cold blocks are stored encoded
//!
//! When the pool is built with a lossy codec
//! ([`KvBlockPool::with_codec`], `--kv-codec f16|int8`), a document's
//! blocks past the `--kv-hot-blocks` watermark are **not** pooled:
//! they live as per-document encoded byte payloads
//! ([`BlockSlot::Encoded`], ~2–4× smaller), decoded on read straight
//! into the caller's f32 scratch ([`super::codec::KvCodec::decode_span`]).
//! The first `hot_blocks` blocks stay as raw pooled f32 — content
//! shared and CoW as before — so the head of every document assembles
//! at full speed. Resident-byte accounting charges **physical** bytes
//! (payload length for encoded blocks), which is what the cache-tier
//! budgets consume.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::codec::{codec_for, KvCodec, CODEC_F32};
use crate::config::KvCodecKind;
use crate::sync::Mutex;
use crate::tensor::Tensor;

/// Default `--kv-block-tokens`: tokens of per-layer K/V per pool block.
pub const DEFAULT_KV_BLOCK_TOKENS: usize = 64;

/// FNV-1a over a slot payload (little-endian `f32` bytes) — the pool's
/// content address for block sharing. Matches the byte-level
/// [`super::store::fnv64`] definition.
fn content_hash(data: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in data {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Pool counters: `slots_*` and `slab_bytes` are gauges (current
/// state), the rest are monotone lifetime totals. `blocks_evicted`,
/// `blocks_spilled`, and `partial_evictions` are noted by the cache
/// tiers (the pool itself only sees alloc/free).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct PoolStats {
    pub slots_total: u64,
    pub slots_live: u64,
    pub slots_free: u64,
    pub slab_bytes: u64,
    pub grow_events: u64,
    pub blocks_evicted: u64,
    pub blocks_spilled: u64,
    pub share_hits: u64,
    pub partial_evictions: u64,
    pub double_frees: u64,
}

struct PoolInner {
    slab: Vec<f32>,
    /// Pinned by the first allocation (0 = not yet pinned).
    per_token_elems: usize,
    slot_elems: usize,
    /// Per-slot reference counts (0 = free).
    refs: Vec<u32>,
    /// Per-slot content hash (stale after a CoW-exempt unique write —
    /// then removed from `by_content`).
    content: Vec<u64>,
    /// Content hash -> slot, for prefix sharing. Always verified
    /// against the actual payload before sharing.
    by_content: HashMap<u64, u32>,
    /// LIFO free list.
    free_slots: Vec<u32>,
    grow_events: u64,
    blocks_evicted: u64,
    blocks_spilled: u64,
    share_hits: u64,
    partial_evictions: u64,
    double_frees: u64,
}

impl PoolInner {
    fn n_slots(&self) -> usize {
        self.refs.len()
    }

    fn slot_base(&self, slot: u32) -> usize {
        slot as usize * self.slot_elems
    }

    /// Double the slab (at least one slot), preserving contents.
    fn grow(&mut self) {
        let add = self.n_slots().max(1);
        let old = self.n_slots();
        self.slab.resize((old + add) * self.slot_elems, 0.0);
        self.refs.resize(old + add, 0);
        self.content.resize(old + add, 0);
        // push in reverse so the lowest new slot is handed out first
        for s in (old..old + add).rev() {
            self.free_slots.push(s as u32);
        }
        self.grow_events += 1;
    }

    /// Pop a free slot, growing the slab when none remain.
    // allow: `grow()` appends `n_slots().max(1)` slots, so the pop
    // cannot miss; a structured error here would force every caller to
    // thread an impossible failure. Tracked in rust/lint_allowlist.txt.
    #[allow(clippy::expect_used)]
    fn take_free(&mut self) -> u32 {
        if self.free_slots.is_empty() {
            self.grow();
        }
        self.free_slots.pop().expect("grow() refills the free list")
    }

    fn forget_content(&mut self, slot: u32) {
        let h = self.content[slot as usize];
        if self.by_content.get(&h) == Some(&slot) {
            self.by_content.remove(&h);
        }
        self.content[slot as usize] = 0;
    }
}

/// The process-wide slab of fixed-size KV block slots (see the module
/// docs). Thread-safe; shared behind an `Arc` by every tier and every
/// [`BlockRef`]. Carries the serving stack's block codec and hot
/// watermark so every [`KvBlocks`] built over it encodes consistently.
pub struct KvBlockPool {
    block_tokens: usize,
    codec: Arc<dyn KvCodec>,
    hot_blocks: usize,
    inner: Mutex<PoolInner>,
}

impl KvBlockPool {
    pub fn new(block_tokens: usize) -> KvBlockPool {
        KvBlockPool {
            block_tokens: block_tokens.max(1),
            codec: codec_for(KvCodecKind::F32),
            hot_blocks: crate::config::DEFAULT_KV_HOT_BLOCKS,
            inner: Mutex::named("pool-inner", PoolInner {
                slab: Vec::new(),
                per_token_elems: 0,
                slot_elems: 0,
                refs: Vec::new(),
                content: Vec::new(),
                by_content: HashMap::new(),
                free_slots: Vec::new(),
                grow_events: 0,
                blocks_evicted: 0,
                blocks_spilled: 0,
                share_hits: 0,
                partial_evictions: 0,
                double_frees: 0,
            }),
        }
    }

    /// Tokens of per-layer K/V per block (`--kv-block-tokens`).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Set the block codec and hot watermark (`--kv-codec` /
    /// `--kv-hot-blocks`): blocks `>= hot_blocks` of each document are
    /// stored encoded when the codec is lossy (a [`super::codec::CODEC_F32`]
    /// codec keeps every block pooled, preserving byte-identical
    /// behavior and content sharing).
    pub fn with_codec(mut self, codec: Arc<dyn KvCodec>,
                      hot_blocks: usize) -> KvBlockPool {
        self.codec = codec;
        self.hot_blocks = hot_blocks;
        self
    }

    /// The stack's block codec (shared with the disk tier).
    pub fn codec(&self) -> &Arc<dyn KvCodec> {
        &self.codec
    }

    /// Per-document count of head blocks kept as raw pooled f32.
    pub fn hot_blocks(&self) -> usize {
        self.hot_blocks
    }

    /// Whether block index `b` of a document is stored encoded (past
    /// the hot watermark, under a lossy codec).
    fn encode_cold(&self, b: usize) -> bool {
        b >= self.hot_blocks && self.codec.id() != CODEC_F32
    }

    pub fn stats(&self) -> PoolStats {
        let g = self.inner.lock();
        let total = g.n_slots() as u64;
        let free = g.free_slots.len() as u64;
        PoolStats {
            slots_total: total,
            slots_live: total - free,
            slots_free: free,
            slab_bytes: (g.slab.len() * 4) as u64,
            grow_events: g.grow_events,
            blocks_evicted: g.blocks_evicted,
            blocks_spilled: g.blocks_spilled,
            share_hits: g.share_hits,
            partial_evictions: g.partial_evictions,
            double_frees: g.double_frees,
        }
    }

    /// Tier-side accounting: blocks removed from an entry by eviction.
    pub fn note_blocks_evicted(&self, n: u64) {
        self.inner.lock().blocks_evicted += n;
    }

    /// Tier-side accounting: blocks written to the disk tier.
    pub fn note_blocks_spilled(&self, n: u64) {
        self.inner.lock().blocks_spilled += n;
    }

    /// Tier-side accounting: an eviction pass left a document partially
    /// resident (block granularity doing its job).
    pub fn note_partial_eviction(&self) {
        self.inner.lock().partial_evictions += 1;
    }

    /// Allocate (or share) a slot holding `data`, padded with zeros to
    /// the slot size. The pool's per-token geometry is pinned by the
    /// first call. Returns the slot id with one reference held.
    fn alloc_slot(&self, per_token_elems: usize, data: &[f32])
                  -> Result<u32> {
        ensure!(per_token_elems > 0, "per_token_elems must be > 0");
        let mut g = self.inner.lock();
        if g.per_token_elems == 0 {
            g.per_token_elems = per_token_elems;
            g.slot_elems = per_token_elems * self.block_tokens;
        } else if g.per_token_elems != per_token_elems {
            bail!("KV geometry mismatch: pool holds {} elems/token, \
                   block has {}", g.per_token_elems, per_token_elems);
        }
        ensure!(data.len() <= g.slot_elems,
                "block payload {} exceeds slot size {}", data.len(),
                g.slot_elems);
        let mut buf = vec![0f32; g.slot_elems];
        buf[..data.len()].copy_from_slice(data);
        let h = content_hash(&buf);
        if let Some(&s) = g.by_content.get(&h) {
            let base = g.slot_base(s);
            let slot_elems = g.slot_elems;
            if g.refs[s as usize] > 0
                && g.slab[base..base + slot_elems] == buf[..]
            {
                g.refs[s as usize] += 1;
                g.share_hits += 1;
                return Ok(s);
            }
        }
        let s = g.take_free();
        let base = g.slot_base(s);
        let slot_elems = g.slot_elems;
        g.slab[base..base + slot_elems].copy_from_slice(&buf);
        g.refs[s as usize] = 1;
        g.content[s as usize] = h;
        g.by_content.insert(h, s);
        Ok(s)
    }

    /// Bump a live slot's refcount ([`BlockRef::clone`]).
    fn retain_slot(&self, slot: u32) {
        let mut g = self.inner.lock();
        debug_assert!(g.refs[slot as usize] > 0, "retain of a free slot");
        g.refs[slot as usize] += 1;
    }

    /// Drop one reference; the slot returns to the free list at zero.
    /// A release of an already-free (or out-of-range) slot is rejected
    /// and counted in [`PoolStats::double_frees`] — never a panic, and
    /// never a corruption of another block's slot.
    pub(crate) fn release_slot(&self, slot: u32) -> bool {
        let mut g = self.inner.lock();
        let s = slot as usize;
        if s >= g.refs.len() || g.refs[s] == 0 {
            g.double_frees += 1;
            return false;
        }
        g.refs[s] -= 1;
        if g.refs[s] == 0 {
            g.forget_content(slot);
            g.free_slots.push(slot);
        }
        true
    }

    /// Copy `dst.len()` elements out of a live slot at `offset`.
    fn read_slot(&self, slot: u32, offset: usize, dst: &mut [f32])
                 -> Result<()> {
        let g = self.inner.lock();
        let s = slot as usize;
        ensure!(s < g.refs.len() && g.refs[s] > 0,
                "read of a free pool slot {slot}");
        ensure!(offset + dst.len() <= g.slot_elems,
                "slot read out of range: {}+{} > {}", offset, dst.len(),
                g.slot_elems);
        let base = g.slot_base(slot);
        dst.copy_from_slice(&g.slab[base + offset..base + offset
                                    + dst.len()]);
        Ok(())
    }

    /// Copy-on-write write through `r`: a slot shared with other refs
    /// is copied to a fresh slot first (the sharers keep the old
    /// payload); a uniquely-held slot is written in place and leaves
    /// the content-sharing index (its payload no longer matches its
    /// address).
    fn write_slot(&self, r: &mut BlockRef, offset: usize, data: &[f32])
                  -> Result<()> {
        let mut g = self.inner.lock();
        let s = r.slot as usize;
        ensure!(s < g.refs.len() && g.refs[s] > 0,
                "write through a dead BlockRef (slot {})", r.slot);
        ensure!(offset + data.len() <= g.slot_elems,
                "slot write out of range: {}+{} > {}", offset, data.len(),
                g.slot_elems);
        if g.refs[s] > 1 {
            // shared: copy to a private slot, move this ref over
            let ns = g.take_free();
            let (ob, nb) = (g.slot_base(r.slot), g.slot_base(ns));
            let payload = g.slab[ob..ob + g.slot_elems].to_vec();
            let slot_elems = g.slot_elems;
            g.slab[nb..nb + slot_elems].copy_from_slice(&payload);
            g.refs[s] -= 1;
            g.refs[ns as usize] = 1;
            g.content[ns as usize] = 0;
            r.slot = ns;
        } else {
            g.forget_content(r.slot);
        }
        let base = g.slot_base(r.slot);
        g.slab[base + offset..base + offset + data.len()]
            .copy_from_slice(data);
        Ok(())
    }
}

impl std::fmt::Debug for KvBlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvBlockPool")
            .field("block_tokens", &self.block_tokens)
            .field("slots_total", &s.slots_total)
            .field("slots_live", &s.slots_live)
            .field("slab_bytes", &s.slab_bytes)
            .finish()
    }
}

/// One counted reference to a pool slot. Cloning shares the slot;
/// dropping releases it; [`Self::write`] is copy-on-write.
pub struct BlockRef {
    pool: Arc<KvBlockPool>,
    slot: u32,
}

impl BlockRef {
    /// Allocate (or content-share) a slot for `data` and return a ref.
    pub fn alloc(pool: &Arc<KvBlockPool>, per_token_elems: usize,
                 data: &[f32]) -> Result<BlockRef> {
        let slot = pool.alloc_slot(per_token_elems, data)?;
        Ok(BlockRef { pool: Arc::clone(pool), slot })
    }

    pub fn slot(&self) -> u32 {
        self.slot
    }

    /// Copy `dst.len()` elements out of the slot at `offset`.
    pub fn read(&self, offset: usize, dst: &mut [f32]) -> Result<()> {
        self.pool.read_slot(self.slot, offset, dst)
    }

    /// Copy-on-write write at `offset` (see [`KvBlockPool`]): sharers
    /// of the slot are unaffected; this ref may move to a fresh slot.
    pub fn write(&mut self, offset: usize, data: &[f32]) -> Result<()> {
        let pool = Arc::clone(&self.pool);
        pool.write_slot(self, offset, data)
    }
}

impl Clone for BlockRef {
    fn clone(&self) -> BlockRef {
        self.pool.retain_slot(self.slot);
        BlockRef { pool: Arc::clone(&self.pool), slot: self.slot }
    }
}

impl Drop for BlockRef {
    fn drop(&mut self) {
        self.pool.release_slot(self.slot);
    }
}

impl std::fmt::Debug for BlockRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BlockRef(slot {})", self.slot)
    }
}

/// Geometry of one document's pooled KV: `[L, 2, H, T, Dh]` split into
/// `ceil(T / block_tokens)` blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLayout {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_tokens: usize,
    pub block_tokens: usize,
}

impl KvLayout {
    pub fn n_blocks(&self) -> usize {
        (self.n_tokens + self.block_tokens - 1) / self.block_tokens
    }

    /// Tokens held by block `b` (the tail block may be partial).
    pub fn block_len(&self, b: usize) -> usize {
        let t0 = b * self.block_tokens;
        self.block_tokens.min(self.n_tokens.saturating_sub(t0))
    }

    /// `f32` elements of K+V per token across all layers/heads.
    pub fn per_token_elems(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.head_dim
    }

    /// `f32` elements per pool slot.
    pub fn slot_elems(&self) -> usize {
        self.per_token_elems() * self.block_tokens
    }

    /// Logical bytes of block `b` (padding excluded).
    pub fn block_bytes(&self, b: usize) -> usize {
        self.block_len(b) * self.per_token_elems() * 4
    }

    fn channel(&self, l: usize, c: usize, h: usize) -> usize {
        (l * 2 + c) * self.n_heads + h
    }
}

/// Pack block `b` of a `[L,2,H,T,Dh]` tensor into slot layout
/// (channel-major, zero-padded tail).
fn slot_from_tensor(lay: &KvLayout, kv: &Tensor, b: usize) -> Vec<f32> {
    let (dh, bt) = (lay.head_dim, lay.block_tokens);
    let t0 = b * bt;
    let len = lay.block_len(b);
    let mut buf = vec![0f32; lay.slot_elems()];
    for l in 0..lay.n_layers {
        for c in 0..2 {
            for h in 0..lay.n_heads {
                let src = kv.slice_at(&[l, c, h]);
                let off = lay.channel(l, c, h) * bt * dh;
                buf[off..off + len * dh]
                    .copy_from_slice(&src[t0 * dh..(t0 + len) * dh]);
            }
        }
    }
    buf
}

/// Trim a slot payload to block `b`'s logical (unpadded, channel-major)
/// form — the disk tier's per-block record layout.
fn logical_from_slot(lay: &KvLayout, b: usize, slot: &[f32]) -> Vec<f32> {
    let (dh, bt) = (lay.head_dim, lay.block_tokens);
    let len = lay.block_len(b);
    let nch = lay.n_layers * 2 * lay.n_heads;
    let mut out = vec![0f32; len * lay.per_token_elems()];
    for ch in 0..nch {
        out[ch * len * dh..(ch + 1) * len * dh]
            .copy_from_slice(&slot[ch * bt * dh..ch * bt * dh + len * dh]);
    }
    out
}

/// Inverse of [`logical_from_slot`]: re-pad a logical block record into
/// slot layout.
fn slot_from_logical(lay: &KvLayout, b: usize, logical: &[f32])
                     -> Vec<f32> {
    let (dh, bt) = (lay.head_dim, lay.block_tokens);
    let len = lay.block_len(b);
    let nch = lay.n_layers * 2 * lay.n_heads;
    let mut buf = vec![0f32; lay.slot_elems()];
    for ch in 0..nch {
        buf[ch * bt * dh..ch * bt * dh + len * dh]
            .copy_from_slice(&logical[ch * len * dh..(ch + 1) * len * dh]);
    }
    buf
}

/// Extract block `b` of a `[L,2,H,T,Dh]` tensor in logical (unpadded,
/// channel-major) form — what a codec encodes.
fn logical_from_tensor(lay: &KvLayout, kv: &Tensor, b: usize) -> Vec<f32> {
    let dh = lay.head_dim;
    let t0 = b * lay.block_tokens;
    let len = lay.block_len(b);
    let mut out = vec![0f32; len * lay.per_token_elems()];
    for l in 0..lay.n_layers {
        for c in 0..2 {
            for h in 0..lay.n_heads {
                let src = kv.slice_at(&[l, c, h]);
                let ch = lay.channel(l, c, h);
                out[ch * len * dh..(ch + 1) * len * dh]
                    .copy_from_slice(&src[t0 * dh..(t0 + len) * dh]);
            }
        }
    }
    out
}

/// How one block of a document is held (see the module docs): hot
/// blocks live in the pool as raw f32, cold blocks as codec-encoded
/// payloads, and an evicted block is a hole.
enum BlockSlot {
    /// Evicted (slot released / payload dropped, possibly spilled).
    Missing,
    /// Raw f32 in a pool slot — content-shared, CoW.
    Pooled(BlockRef),
    /// Codec-encoded logical payload (the pool's codec), decoded on
    /// read. Physical footprint is the payload length.
    Encoded(Vec<u8>),
}

impl BlockSlot {
    fn is_resident(&self) -> bool {
        !matches!(self, BlockSlot::Missing)
    }
}

/// One document's KV as a block-index list over the pool — the storage
/// behind [`super::DocEntry::kv`]. A [`BlockSlot::Missing`] block is
/// evicted (its slot released or payload dropped, possibly spilled to
/// disk); reads of evicted blocks error instead of serving stale data.
/// Interior-mutable (`Mutex`) because tiers evict/restore blocks of
/// entries shared via `Arc`.
pub struct KvBlocks {
    pool: Arc<KvBlockPool>,
    layout: KvLayout,
    blocks: Mutex<Vec<BlockSlot>>,
}

impl KvBlocks {
    /// Split a `[L,2,H,T,Dh]` KV tensor into pool blocks. Identical
    /// blocks (two docs sharing a token prefix) share slots.
    pub fn from_tensor(pool: &Arc<KvBlockPool>, kv: &Tensor)
                       -> Result<KvBlocks> {
        let s = kv.shape();
        ensure!(s.len() == 5 && s[1] == 2,
                "doc kv must be [L,2,H,T,Dh], got {:?}", s);
        let layout = KvLayout {
            n_layers: s[0],
            n_heads: s[2],
            head_dim: s[4],
            n_tokens: s[3],
            block_tokens: pool.block_tokens(),
        };
        let pte = layout.per_token_elems();
        let mut blocks = Vec::with_capacity(layout.n_blocks());
        for b in 0..layout.n_blocks() {
            if pool.encode_cold(b) {
                let logical = logical_from_tensor(&layout, kv, b);
                blocks.push(BlockSlot::Encoded(
                    pool.codec().encode_block(&logical)));
            } else {
                let buf = slot_from_tensor(&layout, kv, b);
                blocks.push(BlockSlot::Pooled(
                    BlockRef::alloc(pool, pte, &buf)?));
            }
        }
        Ok(KvBlocks {
            pool: Arc::clone(pool),
            layout,
            blocks: Mutex::named("kv-blocks", blocks),
        })
    }

    /// An empty (all-evicted) block list with the given geometry — the
    /// disk tier decodes into this, then restores blocks one by one.
    pub fn empty(pool: &Arc<KvBlockPool>, layout: KvLayout) -> KvBlocks {
        let mut blocks = Vec::with_capacity(layout.n_blocks());
        blocks.resize_with(layout.n_blocks(), || BlockSlot::Missing);
        KvBlocks { pool: Arc::clone(pool), layout, blocks: Mutex::named("kv-blocks", blocks) }
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    pub fn pool(&self) -> &Arc<KvBlockPool> {
        &self.pool
    }

    pub fn n_blocks(&self) -> usize {
        self.layout.n_blocks()
    }

    /// Logical bytes of the full document KV (independent of residency
    /// or slot sharing).
    pub fn size_bytes(&self) -> usize {
        self.layout.n_tokens * self.layout.per_token_elems() * 4
    }

    pub fn block_bytes(&self, b: usize) -> usize {
        self.layout.block_bytes(b)
    }

    /// **Physical** bytes currently resident: logical f32 bytes for
    /// pooled blocks, payload length for encoded blocks — what the
    /// cache-tier byte budgets charge.
    pub fn resident_bytes(&self) -> usize {
        let blocks = self.blocks.lock();
        blocks
            .iter()
            .enumerate()
            .map(|(b, s)| match s {
                BlockSlot::Missing => 0,
                BlockSlot::Pooled(_) => self.layout.block_bytes(b),
                BlockSlot::Encoded(p) => p.len(),
            })
            .sum()
    }

    /// Physical bytes of block `b` (`None` if evicted): what evicting
    /// this one block frees from a byte budget.
    pub fn block_physical_bytes(&self, b: usize) -> Option<usize> {
        let blocks = self.blocks.lock();
        match blocks.get(b)? {
            BlockSlot::Missing => None,
            BlockSlot::Pooled(_) => Some(self.layout.block_bytes(b)),
            BlockSlot::Encoded(p) => Some(p.len()),
        }
    }

    pub fn is_fully_resident(&self) -> bool {
        self.blocks.lock().iter().all(|s| s.is_resident())
    }

    pub fn resident_block_indexes(&self) -> Vec<u32> {
        let blocks = self.blocks.lock();
        (0..blocks.len() as u32)
            .filter(|&b| blocks[b as usize].is_resident())
            .collect()
    }

    pub fn missing_block_indexes(&self) -> Vec<u32> {
        let blocks = self.blocks.lock();
        (0..blocks.len() as u32)
            .filter(|&b| !blocks[b as usize].is_resident())
            .collect()
    }

    /// Copy `n_tok` tokens of channel `(l, c, h)` starting at document
    /// token `tok_start` into `dst` (`n_tok * head_dim` elements),
    /// crossing pool-block boundaries as needed. Errors if any covered
    /// block is evicted.
    pub fn copy_span(&self, l: usize, c: usize, h: usize, tok_start: usize,
                     n_tok: usize, dst: &mut [f32]) -> Result<()> {
        let lay = &self.layout;
        let (dh, bt) = (lay.head_dim, lay.block_tokens);
        ensure!(l < lay.n_layers && c < 2 && h < lay.n_heads,
                "channel ({l},{c},{h}) out of range");
        ensure!(tok_start + n_tok <= lay.n_tokens,
                "token span {}..{} exceeds doc length {}", tok_start,
                tok_start + n_tok, lay.n_tokens);
        ensure!(dst.len() == n_tok * dh,
                "dst len {} != {} tokens x {} dims", dst.len(), n_tok, dh);
        let ch = lay.channel(l, c, h);
        let blocks = self.blocks.lock();
        let mut t = tok_start;
        let mut out = 0usize;
        while t < tok_start + n_tok {
            let b = t / bt;
            let local = t - b * bt;
            let run = (lay.block_len(b) - local).min(tok_start + n_tok - t);
            match &blocks[b] {
                BlockSlot::Missing => bail!(
                    "KV block {b} is evicted (tokens {}..{})", b * bt,
                    b * bt + lay.block_len(b)),
                BlockSlot::Pooled(r) => {
                    r.read(ch * bt * dh + local * dh,
                           &mut dst[out..out + run * dh])?;
                }
                // encoded payloads are logical (unpadded): channel
                // stride is the block's own token count, not bt
                BlockSlot::Encoded(p) => {
                    let len = lay.block_len(b);
                    self.pool.codec().decode_span(
                        p, ch * len * dh + local * dh,
                        &mut dst[out..out + run * dh])?;
                }
            }
            t += run;
            out += run * dh;
        }
        Ok(())
    }

    /// Gather the full `[L,2,H,T,Dh]` tensor (errors if any block is
    /// evicted). The escape hatch for dense consumers (scoring paths,
    /// disk round-trip tests); the assemble path uses [`Self::copy_span`]
    /// per block instead.
    pub fn gather(&self) -> Result<Tensor> {
        let lay = self.layout;
        let mut out = Tensor::zeros(&[lay.n_layers, 2, lay.n_heads,
                                      lay.n_tokens, lay.head_dim]);
        for l in 0..lay.n_layers {
            for c in 0..2 {
                for h in 0..lay.n_heads {
                    let dst = out.slice_at_mut(&[l, c, h]);
                    self.copy_span(l, c, h, 0, lay.n_tokens, dst)?;
                }
            }
        }
        Ok(out)
    }

    /// Decode one held block (pooled or encoded) to its logical
    /// payload. Never called on [`BlockSlot::Missing`].
    fn decode_slot(&self, b: usize, slot: &BlockSlot) -> Option<Vec<f32>> {
        match slot {
            BlockSlot::Missing => None,
            BlockSlot::Pooled(r) => {
                let mut buf = vec![0f32; self.layout.slot_elems()];
                r.read(0, &mut buf).ok()?;
                Some(logical_from_slot(&self.layout, b, &buf))
            }
            BlockSlot::Encoded(p) => {
                let mut out = vec![0f32; self.layout.block_len(b)
                                  * self.layout.per_token_elems()];
                self.pool.codec().decode_block(p, &mut out).ok()?;
                Some(out)
            }
        }
    }

    /// Build the slot for block `b` from its logical payload: encoded
    /// past the hot watermark (lossy codec), pooled otherwise.
    fn slot_for(&self, b: usize, logical: &[f32]) -> Result<BlockSlot> {
        if self.pool.encode_cold(b) {
            Ok(BlockSlot::Encoded(self.pool.codec().encode_block(logical)))
        } else {
            let buf = slot_from_logical(&self.layout, b, logical);
            Ok(BlockSlot::Pooled(BlockRef::alloc(
                &self.pool, self.layout.per_token_elems(), &buf)?))
        }
    }

    /// Block `b`'s logical payload (channel-major, unpadded, decoded to
    /// f32), or `None` if evicted — the disk tier's record source.
    pub fn block_data(&self, b: usize) -> Option<Vec<f32>> {
        let blocks = self.blocks.lock();
        self.decode_slot(b, blocks.get(b)?)
    }

    /// Evict block `b`: remove it and return its logical (decoded f32)
    /// payload so the caller can spill it to disk after releasing the
    /// slot. `None` if already evicted.
    pub fn take_block_data(&self, b: usize) -> Option<Vec<f32>> {
        let taken = std::mem::replace(
            self.blocks.lock().get_mut(b)?, BlockSlot::Missing);
        if !taken.is_resident() {
            return None;
        }
        let data = self.decode_slot(b, &taken);
        drop(taken); // releases the pool slot for pooled blocks
        data
    }

    /// Re-admit an evicted block from its logical payload (disk load).
    /// Past the hot watermark the block is re-encoded with the pool's
    /// codec, whatever codec the payload came from on disk.
    pub fn restore_block(&self, b: usize, logical: &[f32]) -> Result<()> {
        let lay = self.layout;
        ensure!(b < lay.n_blocks(), "block {b} out of range");
        ensure!(logical.len() == lay.block_len(b) * lay.per_token_elems(),
                "block {b} payload {} != expected {}", logical.len(),
                lay.block_len(b) * lay.per_token_elems());
        let slot = self.slot_for(b, logical)?;
        let mut blocks = self.blocks.lock();
        ensure!(!blocks[b].is_resident(), "block {b} is already resident");
        blocks[b] = slot;
        Ok(())
    }

    /// Fill every evicted block from a freshly prefilled `[L,2,H,T,Dh]`
    /// tensor (partial re-prefill after eviction when the disk tier
    /// cannot supply the blocks). Returns how many blocks were
    /// installed.
    pub fn install_missing_from(&self, kv: &Tensor) -> Result<usize> {
        let lay = self.layout;
        ensure!(kv.shape() == [lay.n_layers, 2, lay.n_heads, lay.n_tokens,
                               lay.head_dim],
                "kv shape {:?} != layout {:?}", kv.shape(), lay);
        let missing = self.missing_block_indexes();
        for &b in &missing {
            let logical = logical_from_tensor(&lay, kv, b as usize);
            let slot = self.slot_for(b as usize, &logical)?;
            let mut blocks = self.blocks.lock();
            if !blocks[b as usize].is_resident() {
                blocks[b as usize] = slot;
            }
        }
        Ok(missing.len())
    }
}

impl std::fmt::Debug for KvBlocks {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let resident = self.resident_block_indexes().len();
        write!(f, "KvBlocks({} tokens x{} bt, {}/{} resident)",
               self.layout.n_tokens, self.layout.block_tokens, resident,
               self.layout.n_blocks())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(bt: usize) -> Arc<KvBlockPool> {
        Arc::new(KvBlockPool::new(bt))
    }

    /// `[1,2,1,T,2]` tensor tagged so value = channel*1000 + t*10 + d.
    fn tagged_kv(n_tokens: usize) -> Tensor {
        let mut kv = Tensor::zeros(&[1, 2, 1, n_tokens, 2]);
        for c in 0..2 {
            let s = kv.slice_at_mut(&[0, c, 0]);
            for t in 0..n_tokens {
                for d in 0..2 {
                    s[t * 2 + d] = (c * 1000 + t * 10 + d) as f32;
                }
            }
        }
        kv
    }

    #[test]
    fn slot_reuse_after_free() {
        let p = pool(4);
        let a = BlockRef::alloc(&p, 2, &[1.0; 8]).unwrap();
        let first_slot = a.slot();
        drop(a);
        let s = p.stats();
        assert_eq!(s.slots_live, 0);
        assert!(s.slots_free >= 1);
        // the freed slot is handed out again (LIFO), not leaked
        let b = BlockRef::alloc(&p, 2, &[2.0; 8]).unwrap();
        assert_eq!(b.slot(), first_slot, "freed slot must be reused");
        let mut back = [0f32; 8];
        b.read(0, &mut back).unwrap();
        assert_eq!(back, [2.0; 8]);
    }

    #[test]
    fn grow_by_doubling_preserves_contents() {
        let p = pool(2);
        // distinct payloads so content sharing never kicks in
        let refs: Vec<BlockRef> = (0..9)
            .map(|i| {
                BlockRef::alloc(&p, 2, &[i as f32, i as f32 + 0.5, 0.0,
                                         1.0])
                    .unwrap()
            })
            .collect();
        let s = p.stats();
        assert!(s.grow_events >= 3,
                "9 slots from an empty slab needs repeated doubling");
        assert!(s.slots_total >= 9);
        assert_eq!(s.slots_live, 9);
        // every block's payload survived every grow
        for (i, r) in refs.iter().enumerate() {
            let mut back = [0f32; 4];
            r.read(0, &mut back).unwrap();
            assert_eq!(back, [i as f32, i as f32 + 0.5, 0.0, 1.0],
                       "slot {i} corrupted by slab growth");
        }
    }

    #[test]
    fn refcount_and_copy_on_write() {
        let p = pool(4);
        let a = BlockRef::alloc(&p, 1, &[7.0, 8.0, 9.0, 10.0]).unwrap();
        let mut b = a.clone();
        assert_eq!(a.slot(), b.slot(), "clone shares the slot");
        assert_eq!(p.stats().slots_live, 1);
        // writing through one ref must not disturb the other
        b.write(1, &[99.0]).unwrap();
        assert_ne!(a.slot(), b.slot(), "CoW must move the writer");
        let (mut va, mut vb) = ([0f32; 4], [0f32; 4]);
        a.read(0, &mut va).unwrap();
        b.read(0, &mut vb).unwrap();
        assert_eq!(va, [7.0, 8.0, 9.0, 10.0], "sharer saw the write");
        assert_eq!(vb, [7.0, 99.0, 9.0, 10.0]);
        assert_eq!(p.stats().slots_live, 2);
        // dropping both frees both slots
        drop(a);
        drop(b);
        assert_eq!(p.stats().slots_live, 0);
    }

    #[test]
    fn unique_write_stays_in_place() {
        let p = pool(4);
        let mut a = BlockRef::alloc(&p, 1, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let slot = a.slot();
        a.write(0, &[5.0]).unwrap();
        assert_eq!(a.slot(), slot, "sole owner writes in place");
        let mut v = [0f32; 4];
        a.read(0, &mut v).unwrap();
        assert_eq!(v, [5.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn double_free_rejected() {
        let p = pool(4);
        let a = BlockRef::alloc(&p, 1, &[1.0; 4]).unwrap();
        let slot = a.slot();
        drop(a); // legitimate release -> slot is free
        assert!(!p.release_slot(slot), "second free must be rejected");
        assert!(!p.release_slot(999), "out-of-range free rejected");
        assert_eq!(p.stats().double_frees, 2);
        // the slab stays consistent: the slot is reusable exactly once
        let b = BlockRef::alloc(&p, 1, &[2.0; 4]).unwrap();
        assert_eq!(b.slot(), slot);
        assert_eq!(p.stats().slots_live, 1);
    }

    #[test]
    fn identical_content_shares_one_slot() {
        let p = pool(4);
        let a = BlockRef::alloc(&p, 1, &[3.0, 1.0, 4.0, 1.0]).unwrap();
        let b = BlockRef::alloc(&p, 1, &[3.0, 1.0, 4.0, 1.0]).unwrap();
        let c = BlockRef::alloc(&p, 1, &[2.0, 7.0, 1.0, 8.0]).unwrap();
        assert_eq!(a.slot(), b.slot(), "identical payloads share a slot");
        assert_ne!(a.slot(), c.slot());
        let s = p.stats();
        assert_eq!(s.share_hits, 1);
        assert_eq!(s.slots_live, 2);
        // the shared slot survives one sharer dropping
        drop(a);
        let mut v = [0f32; 4];
        b.read(0, &mut v).unwrap();
        assert_eq!(v, [3.0, 1.0, 4.0, 1.0]);
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let p = pool(4);
        let _a = BlockRef::alloc(&p, 2, &[0.0; 8]).unwrap();
        assert!(BlockRef::alloc(&p, 3, &[0.0; 12]).is_err(),
                "mixing per-token geometries must fail loudly");
        assert!(BlockRef::alloc(&p, 2, &[0.0; 9]).is_err(),
                "payload larger than a slot must fail");
    }

    #[test]
    fn kvblocks_roundtrip_and_span_crossing() {
        // 7 tokens over 3-token blocks -> 3 blocks, tail len 1
        let p = pool(3);
        let kv = tagged_kv(7);
        let blocks = KvBlocks::from_tensor(&p, &kv).unwrap();
        assert_eq!(blocks.n_blocks(), 3);
        assert!(blocks.is_fully_resident());
        assert_eq!(blocks.gather().unwrap(), kv);
        // a span crossing two block boundaries (tokens 2..6)
        let mut span = vec![0f32; 4 * 2];
        blocks.copy_span(0, 1, 0, 2, 4, &mut span).unwrap();
        assert_eq!(span,
                   vec![1020.0, 1021.0, 1030.0, 1031.0, 1040.0, 1041.0,
                        1050.0, 1051.0]);
        assert_eq!(blocks.size_bytes(), 7 * 4 * 4); // 7 tok x 4 elems x 4B
        assert_eq!(blocks.block_bytes(2), 1 * 4 * 4); // tail block
    }

    #[test]
    fn evict_restore_block_keeps_payload() {
        let p = pool(3);
        let kv = tagged_kv(7);
        let blocks = KvBlocks::from_tensor(&p, &kv).unwrap();
        let live_before = p.stats().slots_live;
        let taken = blocks.take_block_data(1).expect("resident block");
        assert_eq!(taken.len(), 3 * 4); // 3 tokens x 4 elems/token
        assert!(!blocks.is_fully_resident());
        assert_eq!(blocks.missing_block_indexes(), vec![1]);
        assert_eq!(p.stats().slots_live, live_before - 1,
                   "taken block must release its slot");
        // reads through the hole fail instead of serving stale data
        let mut span = vec![0f32; 2];
        assert!(blocks.copy_span(0, 0, 0, 4, 1, &mut span).is_err());
        assert!(blocks.gather().is_err());
        assert!(blocks.take_block_data(1).is_none(), "already evicted");
        // restore from the spilled payload: bit-identical again
        blocks.restore_block(1, &taken).unwrap();
        assert!(blocks.is_fully_resident());
        assert_eq!(blocks.gather().unwrap(), kv);
        assert!(blocks.restore_block(1, &taken).is_err(),
                "restoring a resident block must fail");
    }

    #[test]
    fn install_missing_refills_from_tensor() {
        let p = pool(3);
        let kv = tagged_kv(7);
        let blocks = KvBlocks::from_tensor(&p, &kv).unwrap();
        blocks.take_block_data(0);
        blocks.take_block_data(2);
        assert_eq!(blocks.install_missing_from(&kv).unwrap(), 2);
        assert!(blocks.is_fully_resident());
        assert_eq!(blocks.gather().unwrap(), kv);
        assert_eq!(blocks.install_missing_from(&kv).unwrap(), 0);
    }

    #[test]
    fn prefix_sharing_across_documents() {
        // two docs with an identical first block share its slot
        let p = pool(3);
        let kv_a = tagged_kv(6);
        let mut kv_b = tagged_kv(6);
        // diverge doc B after token 3 (second block differs)
        for c in 0..2 {
            let s = kv_b.slice_at_mut(&[0, c, 0]);
            for x in s[3 * 2..].iter_mut() {
                *x += 0.25;
            }
        }
        let a = KvBlocks::from_tensor(&p, &kv_a).unwrap();
        let b = KvBlocks::from_tensor(&p, &kv_b).unwrap();
        assert_eq!(p.stats().share_hits, 1, "shared prefix block");
        assert_eq!(p.stats().slots_live, 3, "2 + 2 blocks in 3 slots");
        // eviction of the shared block from one doc leaves the other
        a.take_block_data(0).unwrap();
        assert_eq!(b.gather().unwrap(), kv_b,
                   "sharer must survive the other's eviction");
    }

    #[test]
    fn resident_bytes_track_partial_eviction() {
        let p = pool(3);
        let blocks = KvBlocks::from_tensor(&p, &tagged_kv(7)).unwrap();
        assert_eq!(blocks.resident_bytes(), blocks.size_bytes());
        blocks.take_block_data(2); // tail block: 1 token
        assert_eq!(blocks.resident_bytes(),
                   blocks.size_bytes() - blocks.block_bytes(2));
        assert_eq!(blocks.resident_block_indexes(), vec![0, 1]);
    }

    fn coded_pool(bt: usize, kind: KvCodecKind, hot: usize)
                  -> Arc<KvBlockPool> {
        Arc::new(KvBlockPool::new(bt).with_codec(codec_for(kind), hot))
    }

    fn assert_close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            assert!((x - y).abs() <= tol, "{x} vs {y} (tol {tol})");
        }
    }

    #[test]
    fn cold_blocks_encode_past_hot_watermark() {
        // 7 tokens over 3-token blocks: block 0 hot (pooled), 1+2 cold
        let p = coded_pool(3, KvCodecKind::Int8, 1);
        let kv = tagged_kv(7);
        let blocks = KvBlocks::from_tensor(&p, &kv).unwrap();
        assert!(blocks.is_fully_resident());
        assert_eq!(blocks.resident_block_indexes(), vec![0, 1, 2]);
        assert_eq!(p.stats().slots_live, 1,
                   "only the hot block takes a pool slot");
        // physical accounting: hot block logical, cold blocks payload
        let b1 = blocks.block_physical_bytes(1).unwrap();
        assert!(b1 < blocks.block_bytes(1),
                "encoded block must be smaller than f32 ({b1})");
        assert_eq!(blocks.resident_bytes(),
                   blocks.block_bytes(0) + b1
                   + blocks.block_physical_bytes(2).unwrap());
        // the hot block reads back bit-exact
        let mut head = vec![0f32; 3 * 2];
        blocks.copy_span(0, 1, 0, 0, 3, &mut head).unwrap();
        assert_eq!(head, vec![1000.0, 1001.0, 1010.0, 1011.0, 1020.0,
                              1021.0]);
        // cold blocks dequantize within half an int8 step of absmax
        let tol = (1051.0 / 127.0) * 0.5 + 1e-3;
        assert_close(&blocks.gather().unwrap(), &kv, tol);
        // a span crossing the hot/cold boundary decodes both sides
        let mut span = vec![0f32; 4 * 2];
        blocks.copy_span(0, 0, 0, 2, 4, &mut span).unwrap();
        for (i, t) in (2..6).enumerate() {
            for d in 0..2 {
                let want = (t * 10 + d) as f32;
                assert!((span[i * 2 + d] - want).abs() <= tol);
            }
        }
    }

    #[test]
    fn encoded_take_restore_roundtrip() {
        let p = coded_pool(3, KvCodecKind::F16, 0); // everything cold
        let kv = tagged_kv(7);
        let blocks = KvBlocks::from_tensor(&p, &kv).unwrap();
        assert_eq!(p.stats().slots_live, 0, "no pooled blocks at all");
        let taken = blocks.take_block_data(1).expect("resident block");
        assert_eq!(taken.len(), 3 * 4);
        assert_eq!(blocks.missing_block_indexes(), vec![1]);
        assert!(blocks.block_physical_bytes(1).is_none());
        let mut span = vec![0f32; 2];
        assert!(blocks.copy_span(0, 0, 0, 4, 1, &mut span).is_err(),
                "reads through the hole must fail");
        assert!(blocks.take_block_data(1).is_none(), "already evicted");
        blocks.restore_block(1, &taken).unwrap();
        assert!(blocks.is_fully_resident());
        // decode -> encode -> decode is stable within f16 tolerance
        let tol = 1051.0 * 2f32.powi(-11) * 1.01;
        assert_close(&blocks.gather().unwrap(), &kv, tol);
        assert!(blocks.restore_block(1, &taken).is_err(),
                "restoring a resident block must fail");
    }

    #[test]
    fn f32_codec_keeps_every_block_pooled() {
        // an explicit f32 codec with watermark 0 must change nothing:
        // all blocks pooled, byte-identical, physical == logical
        let p = coded_pool(3, KvCodecKind::F32, 0);
        let kv = tagged_kv(7);
        let blocks = KvBlocks::from_tensor(&p, &kv).unwrap();
        assert_eq!(p.stats().slots_live, 3);
        assert_eq!(blocks.resident_bytes(), blocks.size_bytes());
        assert_eq!(blocks.gather().unwrap(), kv);
    }

    #[test]
    fn tier_accounting_notes() {
        let p = pool(4);
        p.note_blocks_evicted(3);
        p.note_blocks_spilled(2);
        p.note_partial_eviction();
        let s = p.stats();
        assert_eq!((s.blocks_evicted, s.blocks_spilled,
                    s.partial_evictions), (3, 2, 1));
    }
}
