//! Content-addressed document KV cache with LRU eviction.
//!
//! In the paper's RAG setting, retrieved documents recur across requests
//! and their KV caches are computed once and stored ("context caching").
//! The store hashes document token content (FNV-1a), keeps the prefill
//! outputs (`kv`, attention maps, local Q), and evicts least-recently-
//! used unpinned entries when a byte budget is exceeded.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::model::{Model, PrefillDocOut};
use crate::tensor::Tensor;

/// FNV-1a over token ids — the document cache key.
pub fn doc_hash(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// A cached document: prefill outputs + bookkeeping.
#[derive(Debug)]
pub struct DocEntry {
    pub hash: u64,
    pub tokens: Vec<i32>,
    /// `[L, 2, H, Ld, Dh]`, local (position 0-based) RoPE.
    pub kv: Tensor,
    /// `[L, H, Ld, Ld]` attention probabilities.
    pub attn: Tensor,
    /// `[L, H, Dh]` local-window mean Q (Eq. 1 bias source).
    pub q_local: Tensor,
    pub bytes: usize,
}

impl DocEntry {
    fn new(tokens: Vec<i32>, out: PrefillDocOut) -> DocEntry {
        let bytes = out.kv.size_bytes() + out.attn.size_bytes()
            + out.q_local.size_bytes();
        DocEntry {
            hash: doc_hash(&tokens),
            tokens,
            kv: out.kv,
            attn: out.attn,
            q_local: out.q_local,
            bytes,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub current_bytes: usize,
    pub peak_bytes: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// LRU document cache. Entries are `Rc` so in-flight requests keep
/// evicted entries alive until they finish.
pub struct CacheStore {
    entries: HashMap<u64, (Rc<DocEntry>, u64)>, // value: (entry, last_use)
    clock: u64,
    budget_bytes: usize,
    stats: CacheStats,
}

impl CacheStore {
    pub fn new(budget_bytes: usize) -> CacheStore {
        CacheStore {
            entries: HashMap::new(),
            clock: 0,
            budget_bytes,
            stats: CacheStats::default(),
        }
    }

    /// Unbounded store (eval harness).
    pub fn unbounded() -> CacheStore {
        Self::new(usize::MAX)
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, tokens: &[i32]) -> bool {
        self.entries.contains_key(&doc_hash(tokens))
    }

    /// Fetch the document's KV cache, prefilling (at local positions,
    /// offset 0 — the multiple-context regime) on a miss.
    pub fn get_or_prefill(&mut self, model: &Model, tokens: &[i32])
                          -> Result<(Rc<DocEntry>, bool)> {
        let h = doc_hash(tokens);
        self.clock += 1;
        if let Some((e, last)) = self.entries.get_mut(&h) {
            *last = self.clock;
            self.stats.hits += 1;
            return Ok((e.clone(), true));
        }
        self.stats.misses += 1;
        let out = model.prefill_doc(tokens, 0)?;
        let entry = Rc::new(DocEntry::new(tokens.to_vec(), out));
        self.stats.current_bytes += entry.bytes;
        self.stats.peak_bytes =
            self.stats.peak_bytes.max(self.stats.current_bytes);
        self.entries.insert(h, (entry.clone(), self.clock));
        self.evict_to_budget();
        Ok((entry, false))
    }

    /// Insert a pre-computed entry (tests / replay).
    pub fn insert(&mut self, tokens: Vec<i32>, out: PrefillDocOut) {
        self.clock += 1;
        let entry = Rc::new(DocEntry::new(tokens, out));
        self.stats.current_bytes += entry.bytes;
        self.stats.peak_bytes =
            self.stats.peak_bytes.max(self.stats.current_bytes);
        self.entries.insert(entry.hash, (entry, self.clock));
        self.evict_to_budget();
    }

    fn evict_to_budget(&mut self) {
        while self.stats.current_bytes > self.budget_bytes
            && self.entries.len() > 1
        {
            // evict the least-recently-used entry
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, (_, last))| *last)
                .map(|(h, _)| *h);
            let Some(h) = victim else { break };
            if let Some((e, _)) = self.entries.remove(&h) {
                self.stats.current_bytes -= e.bytes;
                self.stats.evictions += 1;
            }
        }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.stats.current_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PrefillDocOut;

    fn fake_entry(tokens: Vec<i32>, bytes_hint: usize) -> PrefillDocOut {
        // bytes = (kv + attn + q_local) * 4; use kv only for sizing
        PrefillDocOut {
            kv: Tensor::zeros(&[1, 2, 1, bytes_hint / 8, 1]),
            attn: Tensor::zeros(&[1, 1, 1, 1]),
            q_local: Tensor::zeros(&[1, 1, 1]),
        }
    }

    #[test]
    fn hash_is_content_based() {
        assert_eq!(doc_hash(&[1, 2, 3]), doc_hash(&[1, 2, 3]));
        assert_ne!(doc_hash(&[1, 2, 3]), doc_hash(&[1, 2, 4]));
        assert_ne!(doc_hash(&[1, 2]), doc_hash(&[2, 1]));
    }

    #[test]
    fn insert_and_contains() {
        let mut s = CacheStore::unbounded();
        s.insert(vec![1, 2, 3], fake_entry(vec![1, 2, 3], 64));
        assert!(s.contains(&[1, 2, 3]));
        assert!(!s.contains(&[9, 9]));
        assert_eq!(s.len(), 1);
        assert!(s.stats().current_bytes > 0);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        // each entry: kv 32 elems (128B) + attn 4B + q_local 4B = 136B
        let mut s = CacheStore::new(300);
        s.insert(vec![1], fake_entry(vec![1], 128));
        s.insert(vec![2], fake_entry(vec![2], 128));
        assert_eq!(s.len(), 2);
        s.insert(vec![3], fake_entry(vec![3], 128));
        assert!(s.stats().evictions >= 1);
        assert!(s.stats().current_bytes <= 300);
        // entry 1 was the LRU victim
        assert!(!s.contains(&[1]));
        assert!(s.contains(&[3]));
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut s = CacheStore::unbounded();
        s.insert(vec![1], fake_entry(vec![1], 128));
        let p1 = s.stats().peak_bytes;
        s.insert(vec![2], fake_entry(vec![2], 128));
        assert!(s.stats().peak_bytes > p1);
        s.clear();
        assert_eq!(s.stats().current_bytes, 0);
        assert!(s.stats().peak_bytes > p1);
    }
}
